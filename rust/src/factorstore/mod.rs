//! [`FactorStore`] — the amortization layer the paper assumes (§4.3,
//! Table 4: 4.79 s of offline SVD for SwinV2, ~0.05% once amortized).
//!
//! Decomposition used to be a per-`plan()` tax: every call on a
//! `StaticLearned` table re-ran the full Jacobi SVD, every `Dynamic`
//! spec re-fitted its neural factor functions. The store turns that
//! into a content-addressed cache shared across planner, coordinator
//! and server:
//!
//! * **Content-addressed.** Keys are [`Fingerprint`]s: an FNV-1a hash
//!   of the bias kind + geometry + the exact bytes of its tables /
//!   sources (see [`crate::plan::BiasSpec::fingerprint`]). The planner
//!   mixes in the decomposition policy (energy target, rank override,
//!   neural config) so a different policy never aliases a cached
//!   result.
//! * **Thread-safe, decompose-once.** Concurrent `get_or_insert_with`
//!   calls for the same key run the decomposition exactly once; the
//!   other callers block on the in-flight cell and share the finished
//!   [`Factors`] behind an `Arc` (zero copies on a hit).
//! * **Byte-budget LRU with a spill tier.** Factor strips are
//!   Θ((N+M)·R) each (Thm 3.2); the store evicts least-recently-used
//!   entries once the resident bytes exceed the budget. With a spill
//!   file attached ([`FactorStore::spill_to`]) evicted entries move
//!   down a memory tier instead of being dropped: they are appended to
//!   the file (same jsonlite entry encoding as [`FactorStore::save`])
//!   and reloaded on demand — a budgeted store degrades to one disk
//!   read (`spill_hits`), never to a repeated SVD.
//! * **Sharing tier.** A [`remote::RemoteStore`] client attached via
//!   [`FactorStore::attach_remote`] is consulted on a local+spill miss
//!   before decomposing; fetched factors are cached locally
//!   (`remote_hits`). The serving side is [`remote::FactorService`] —
//!   lookup-by-fingerprint over a length-prefixed jsonlite TCP
//!   protocol — so a fleet warms from one decomposition.
//! * **Persistent.** [`FactorStore::save`] / [`FactorStore::load`]
//!   round-trip the store (resident *and* spilled entries) through a
//!   jsonlite file, so offline decomposition (`flashbias warm`)
//!   survives process restarts and a serving fleet can boot warm.
//!
//! Lookup order is always resident → spill → remote → decompose.

pub mod remote;

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use anyhow::{anyhow, Result};

use crate::util::sync::{check_blocking, Mutex};

use crate::decompose::Factors;
use crate::jsonlite::Json;
use crate::tensor::{StripDType, StripPayload, Tensor};

pub use remote::{FactorService, RemoteStore};

// ---------------------------------------------------------------------------
// Fingerprints
// ---------------------------------------------------------------------------

/// 64-bit content fingerprint — the store's key currency.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint(pub u64);

impl Fingerprint {
    pub fn as_u64(self) -> u64 {
        self.0
    }
}

impl std::fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// FNV-1a 64-bit streaming hasher (no `std::hash` — we need a stable,
/// documented digest that survives process restarts and toolchain
/// upgrades, because fingerprints are persisted in store files).
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    pub fn new() -> Self {
        Self(Self::OFFSET)
    }

    #[inline]
    pub fn write_byte(&mut self, b: u8) {
        self.0 = (self.0 ^ b as u64).wrapping_mul(Self::PRIME);
    }

    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_byte(b);
        }
    }

    pub fn write_str(&mut self, s: &str) {
        self.write_bytes(s.as_bytes());
        self.write_byte(0xff); // delimiter: "ab","c" != "a","bc"
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bytes(&x.to_le_bytes());
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write_bytes(&x.to_le_bytes());
    }

    /// Hash f32 payloads by exact bit pattern, one FNV round per 32-bit
    /// word — 4× fewer multiplies than the byte-wise feed on the hot
    /// table path (fingerprints re-hash the table on every
    /// store-addressed plan). A one-ulp perturbation of any entry still
    /// yields a different fingerprint.
    pub fn write_f32s(&mut self, xs: &[f32]) {
        self.write_u64(xs.len() as u64);
        for &x in xs {
            self.0 = (self.0 ^ x.to_bits() as u64)
                .wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(&self) -> Fingerprint {
        Fingerprint(self.0)
    }
}

// ---------------------------------------------------------------------------
// Cached values
// ---------------------------------------------------------------------------

/// What one decomposition attempt produced — the store caches *outcomes*,
/// not just factor strips, so a repeated plan skips the spectrum scan
/// even when the verdict was "stay dense".
#[derive(Clone, Debug)]
pub enum Cached {
    /// Shared factor strips (SVD or neural).
    Factors(Arc<Factors>),
    /// The measured spectral rank failed the planner's low-rank test;
    /// remembered so repeated plans skip the (full-SVD) spectrum scan
    /// and fall back to dense immediately.
    Rejected { measured_rank: usize },
}

impl Cached {
    /// Resident bytes this entry charges against the store budget.
    pub fn size_bytes(&self) -> usize {
        match self {
            Cached::Factors(f) => f.size_bytes(),
            Cached::Rejected { .. } => std::mem::size_of::<usize>(),
        }
    }

    /// The shared factors, when this entry holds any.
    pub fn factors(&self) -> Option<&Arc<Factors>> {
        match self {
            Cached::Factors(f) => Some(f),
            Cached::Rejected { .. } => None,
        }
    }
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct Entry {
    value: Cached,
    bytes: usize,
    /// Monotonic recency stamp — larger = more recently used.
    stamp: u64,
}

/// All four tier maps are `BTreeMap`s: `save` walks them directly, so
/// key order here is the record order of the persisted store file —
/// two stores with the same contents serialize byte-identically.
#[derive(Debug, Default)]
struct Inner {
    map: BTreeMap<u64, Entry>,
    /// In-flight decompositions: concurrent callers share one cell so
    /// the closure runs exactly once per key.
    pending: BTreeMap<u64, Arc<OnceLock<Cached>>>,
    /// Spill-tier index: key → (offset, byte length) of the entry's
    /// jsonlite record in the spill file.
    spill_index: BTreeMap<u64, (u64, u64)>,
    /// Entries displaced by the budget whose spill-file append has not
    /// completed yet (the write happens outside the lock). Staged here
    /// so that, at every instant, an entry is visible in at least one
    /// tier — lookups serve from it and `save` persists it; without
    /// this, a concurrent `save` in the eviction window would silently
    /// drop the entry from the persisted file.
    spilling: BTreeMap<u64, Cached>,
    bytes: usize,
    tick: u64,
}

/// The append-only spill file behind the eviction tier. Offsets of
/// already-written records never move, so the index in [`Inner`] stays
/// valid across appends; re-spilling a key overwrites its index slot
/// and leaves the old record as dead bytes (compaction is a rewrite
/// via [`FactorStore::save`]).
#[derive(Debug)]
struct SpillFile {
    file: std::fs::File,
    /// Append position (we also seek for reads, so the OS cursor is
    /// not authoritative).
    end: u64,
    path: PathBuf,
}

/// Counter snapshot for metrics/CLIs.
#[derive(Clone, Copy, Debug, Default)]
pub struct StoreStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Resident misses served by reloading a spilled entry (one disk
    /// read instead of a repeated decomposition).
    pub spill_hits: u64,
    /// Local+spill misses served by fetching from a peer's
    /// [`remote::FactorService`] instead of decomposing.
    pub remote_hits: u64,
    pub entries: usize,
    /// Entries currently living in the spill tier.
    pub spilled: usize,
    pub bytes: usize,
    /// `usize::MAX` = unbounded.
    pub budget_bytes: usize,
}

impl StoreStats {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        let budget = if self.budget_bytes == usize::MAX {
            "unbounded".to_string()
        } else {
            crate::util::human_bytes(self.budget_bytes as u64)
        };
        format!(
            "store: hits={} misses={} evictions={} spill_hits={} \
             remote_hits={} entries={} spilled={} bytes={} budget={budget}",
            self.hits,
            self.misses,
            self.evictions,
            self.spill_hits,
            self.remote_hits,
            self.entries,
            self.spilled,
            crate::util::human_bytes(self.bytes as u64),
        )
    }

    /// Metrics-dump shape (`coordinator::Metrics::to_json` embeds this).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("evictions", Json::num(self.evictions as f64)),
            ("spill_hits", Json::num(self.spill_hits as f64)),
            ("remote_hits", Json::num(self.remote_hits as f64)),
            ("entries", Json::num(self.entries as f64)),
            ("spilled", Json::num(self.spilled as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            (
                "budget_bytes",
                if self.budget_bytes == usize::MAX {
                    Json::Null
                } else {
                    Json::num(self.budget_bytes as f64)
                },
            ),
        ])
    }
}

/// Thread-safe, content-addressed factor store with a byte-budget LRU,
/// an optional spill-to-disk eviction tier, and an optional remote
/// sharing tier.
pub struct FactorStore {
    inner: Mutex<Inner>,
    spill: Option<Mutex<SpillFile>>,
    remote: Mutex<Option<RemoteStore>>,
    budget_bytes: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    spill_hits: AtomicU64,
    remote_hits: AtomicU64,
}

impl std::fmt::Debug for FactorStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        write!(
            f,
            "FactorStore(entries={}, spilled={}, bytes={}, hits={}, \
             misses={})",
            s.entries, s.spilled, s.bytes, s.hits, s.misses
        )
    }
}

/// How a `get_or_insert_with` miss was ultimately filled — decides
/// which counter ticks.
enum Fill {
    Spill,
    Remote,
    Decomposed,
}

impl FactorStore {
    /// Store bounded to `budget_bytes` of resident factor data.
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            inner: Mutex::new("factorstore.inner", Inner::default()),
            spill: None,
            remote: Mutex::new("factorstore.remote", None),
            budget_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            spill_hits: AtomicU64::new(0),
            remote_hits: AtomicU64::new(0),
        }
    }

    /// Store with no byte budget (nothing is ever evicted).
    pub fn unbounded() -> Self {
        Self::new(usize::MAX)
    }

    /// Attach a spill file: from now on, byte-budget evictions append
    /// the entry to `path` (truncated here — the spill tier is process
    /// scratch, not the persistent store file) instead of dropping it,
    /// and lookups fall back to the spill index on a resident miss.
    pub fn spill_to(mut self, path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| anyhow!("spill file {}: {e}", path.display()))?;
        self.spill = Some(Mutex::new("factorstore.spill", SpillFile { file, end: 0, path }));
        Ok(self)
    }

    /// Attach a sharing-tier client: local+spill misses in
    /// [`Self::get_or_insert_with`] consult this peer before running
    /// the decomposition, and cache what it returns locally.
    pub fn attach_remote(&self, remote: RemoteStore) {
        *self.remote.lock_recover() = Some(remote);
    }

    /// Builder form of [`Self::attach_remote`].
    pub fn with_remote(self, remote: RemoteStore) -> Self {
        self.attach_remote(remote);
        self
    }

    /// The attached sharing-tier client, if any.
    pub fn remote(&self) -> Option<RemoteStore> {
        self.remote.lock_recover().clone()
    }

    /// Look up a finished entry (LRU touch), falling back to the spill
    /// tier on a resident miss — a spilled entry is reloaded from disk,
    /// made resident again, and counted as a `spill_hit`. Counts a hit
    /// or a miss otherwise.
    pub fn get(&self, key: Fingerprint) -> Option<Cached> {
        self.lookup(key, true)
    }

    /// One lookup body behind both [`Self::get`] and [`Self::peek`]:
    /// resident touch, then spill reload + re-insert; `counted` decides
    /// whether the tier counters tick.
    fn lookup(&self, key: Fingerprint, counted: bool) -> Option<Cached> {
        let found = {
            let mut inner = self.inner.lock_recover();
            inner.tick += 1;
            let stamp = inner.tick;
            inner.map.get_mut(&key.0).map(|e| {
                e.stamp = stamp;
                e.value.clone()
            })
        };
        if let Some(v) = found {
            if counted {
                self.hits.fetch_add(1, Ordering::Relaxed);
            }
            return Some(v);
        }
        if let Some(v) = self.spill_take(key) {
            if counted {
                self.spill_hits.fetch_add(1, Ordering::Relaxed);
            }
            self.insert(key, v.clone());
            return Some(v);
        }
        if counted {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        None
    }

    /// [`Self::get`] without touching the hit/miss counters — the
    /// lookup path for *peer* traffic ([`remote::FactorService`]), so
    /// a follower probing for content the leader lacks does not mark
    /// the leader's store dirty or masquerade as local SVD work in its
    /// metrics. Serves the resident and spill tiers (a spilled entry
    /// is made resident again, uncounted).
    pub fn peek(&self, key: Fingerprint) -> Option<Cached> {
        self.lookup(key, false)
    }

    /// Get the entry for `key`, working down the tiers on a resident
    /// miss: reload from the spill file (`spill_hits`), fetch from the
    /// attached remote peer (`remote_hits`), and only then run
    /// `decompose` (`misses`). Concurrent callers for the same key do
    /// the fill exactly once: one caller works, the rest block on the
    /// in-flight cell and share the result (each such share counts as a
    /// hit — they did no decomposition or IO work).
    pub fn get_or_insert_with(
        &self,
        key: Fingerprint,
        decompose: impl FnOnce() -> Cached,
    ) -> Cached {
        let cell = {
            let mut inner = self.inner.lock_recover();
            inner.tick += 1;
            let stamp = inner.tick;
            if let Some(e) = inner.map.get_mut(&key.0) {
                e.stamp = stamp;
                let v = e.value.clone();
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return v;
            }
            inner
                .pending
                .entry(key.0)
                .or_insert_with(|| Arc::new(OnceLock::new()))
                .clone()
        };
        // The store lock is NOT held while filling: only same-key
        // callers wait here, everyone else proceeds.
        let mut fill: Option<Fill> = None;
        let value = cell
            .get_or_init(|| {
                if let Some(v) = self.spill_take(key) {
                    fill = Some(Fill::Spill);
                    return v;
                }
                if let Some(v) = self.remote_fetch(key) {
                    fill = Some(Fill::Remote);
                    return v;
                }
                fill = Some(Fill::Decomposed);
                decompose()
            })
            .clone();
        match fill {
            // we waited on another caller's in-flight fill
            None => self.hits.fetch_add(1, Ordering::Relaxed),
            Some(Fill::Spill) => {
                self.spill_hits.fetch_add(1, Ordering::Relaxed)
            }
            Some(Fill::Remote) => {
                self.remote_hits.fetch_add(1, Ordering::Relaxed)
            }
            Some(Fill::Decomposed) => {
                self.misses.fetch_add(1, Ordering::Relaxed)
            }
        };
        let evicted = {
            let mut inner = self.inner.lock_recover();
            // Only the cell we actually waited on may be retired: after
            // an eviction, a *newer* in-flight decomposition for this
            // key can own a fresh pending cell, and a late waiter from
            // the old one must not remove it (that would let a third
            // caller re-run the work) or clobber the map with its stale
            // value.
            let owns_cell = inner
                .pending
                .get(&key.0)
                .is_some_and(|c| Arc::ptr_eq(c, &cell));
            if owns_cell {
                inner.pending.remove(&key.0);
                if !inner.map.contains_key(&key.0) {
                    self.insert_locked(&mut inner, key.0, value.clone())
                } else {
                    // already resident (another path re-inserted it):
                    // retire any staging slot a spill reload left
                    inner.spilling.remove(&key.0);
                    Vec::new()
                }
            } else {
                Vec::new()
            }
        };
        self.spill_evicted(evicted);
        value
    }

    /// Insert (or replace) an entry directly — the load path.
    pub fn insert(&self, key: Fingerprint, value: Cached) {
        let evicted = {
            let mut inner = self.inner.lock_recover();
            if let Some(old) = inner.map.remove(&key.0) {
                inner.bytes -= old.bytes;
            }
            self.insert_locked(&mut inner, key.0, value)
        };
        self.spill_evicted(evicted);
    }

    /// Insert under the lock, returning the entries the byte budget
    /// displaced. With a spill tier the caller hands them to
    /// [`Self::spill_evicted`] AFTER releasing the lock — serializing
    /// factor strips to disk must not stall every concurrent lookup.
    #[must_use]
    fn insert_locked(&self, inner: &mut Inner, key: u64,
                     value: Cached) -> Vec<(u64, Cached)> {
        inner.tick += 1;
        let stamp = inner.tick;
        let bytes = value.size_bytes();
        inner.bytes += bytes;
        // an entry becoming resident covers every lower tier: drop its
        // (now redundant) spill-index and staging slots
        inner.spill_index.remove(&key);
        inner.spilling.remove(&key);
        inner.map.insert(key, Entry { value, bytes, stamp });
        // strict byte budget: evict LRU-first until back under — but
        // never the entry we are inserting. An entry larger than the
        // whole budget used to evict *itself* right here, so every
        // later plan re-ran the full SVD (silent thrash); instead it
        // stays resident, over budget, until a later insert displaces
        // it into the spill tier.
        let mut evicted = Vec::new();
        while inner.bytes > self.budget_bytes {
            let lru = inner
                .map
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k);
            let Some(k) = lru else { break };
            if let Some(e) = inner.map.remove(&k) {
                inner.bytes -= e.bytes;
                // spill tier: hand the entry down a level instead of
                // dropping it — staged in `spilling` under this lock
                // (still visible to lookups and `save`), appended to
                // the file by the caller outside the lock
                if self.spill.is_some() {
                    inner.spilling.insert(k, e.value.clone());
                    evicted.push((k, e.value));
                }
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        evicted
    }

    /// Append displaced entries to the spill file, publish their index
    /// slots, and retire their staging slots. The file IO runs WITHOUT
    /// the store lock held; the `spilling` staging map keeps the
    /// entries visible to lookups and `save` throughout. Non-finite
    /// payloads have no JSON form and are dropped, exactly as in
    /// [`Self::save`].
    fn spill_evicted(&self, evicted: Vec<(u64, Cached)>) {
        if evicted.is_empty() {
            return;
        }
        let Some(spill) = &self.spill else { return };
        let mut locs = Vec::with_capacity(evicted.len());
        for (k, v) in &evicted {
            if let Some(loc) = spill_append(spill, *k, v) {
                locs.push((*k, loc));
            }
        }
        let mut inner = self.inner.lock_recover();
        for (k, _) in &evicted {
            inner.spilling.remove(k);
        }
        for (k, loc) in locs {
            // the key may have been re-filled and be resident again by
            // now — never shadow a live entry with a stale spill slot
            if !inner.map.contains_key(&k) {
                inner.spill_index.insert(k, loc);
            }
        }
    }

    /// Reload `key` from the spill tier. An entry still staged for
    /// spilling (its file append is in flight on another thread) is
    /// served straight from the staging map. A successful file reload
    /// moves the entry index→staging atomically with respect to the
    /// store lock, so a concurrent [`Self::save`] always sees it in
    /// some tier until the caller re-inserts it (insertion retires the
    /// staging slot). An IO/parse failure consumes the slot and
    /// degrades to a miss (the caller decomposes again).
    fn spill_take(&self, key: Fingerprint) -> Option<Cached> {
        self.spill.as_ref()?;
        let loc = {
            let inner = self.inner.lock_recover();
            if let Some(v) = inner.spilling.get(&key.0) {
                return Some(v.clone());
            }
            *inner.spill_index.get(&key.0)?
        };
        let parsed = self.spill_read_at(loc);
        let mut inner = self.inner.lock_recover();
        // consume the slot only if it still points at what we read — a
        // concurrent re-spill owns the newer record
        if inner.spill_index.get(&key.0) == Some(&loc) {
            inner.spill_index.remove(&key.0);
        }
        match parsed {
            Some((k, v)) if k == key => {
                // stay visible to save()/lookups until re-inserted
                inner.spilling.insert(key.0, v.clone());
                Some(v)
            }
            _ => None,
        }
    }

    /// Read and decode one spill record without touching the index.
    fn spill_read_at(&self, (offset, len): (u64, u64))
                     -> Option<(Fingerprint, Cached)> {
        // flashlint: allow-fn(io-under-lock) the spill-file lock exists to serialize this seek+read pair; the store's global lock is never held here (enforced at runtime by check_blocking)
        let spill = self.spill.as_ref()?;
        let text = {
            let mut f = spill.lock_recover();
            check_blocking(
                "factorstore::spill_read_at",
                &["factorstore.spill"],
            );
            if f.file.seek(SeekFrom::Start(offset)).is_err() {
                return None;
            }
            let mut buf = vec![0u8; len as usize];
            if f.file.read_exact(&mut buf).is_err() {
                return None;
            }
            String::from_utf8(buf).ok()?
        };
        let json = Json::parse(&text).ok()?;
        entry_from_json(&json).ok()
    }

    /// Fetch `key` from the attached sharing-tier peer, if any.
    /// Network/protocol failures degrade to `None` (decompose locally).
    /// The client is cloned out of its lock first: the socket round
    /// trip must never run under any store lock.
    fn remote_fetch(&self, key: Fingerprint) -> Option<Cached> {
        let remote = self.remote.lock_recover().clone()?;
        check_blocking("factorstore::remote_fetch", &[]);
        remote.fetch(key)
    }

    pub fn len(&self) -> usize {
        self.inner.lock_recover().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.lock_recover().map.is_empty()
    }

    /// Resident factor bytes.
    pub fn total_bytes(&self) -> usize {
        self.inner.lock_recover().bytes
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn spill_hits(&self) -> u64 {
        self.spill_hits.load(Ordering::Relaxed)
    }

    pub fn remote_hits(&self) -> u64 {
        self.remote_hits.load(Ordering::Relaxed)
    }

    /// Entries currently living in the spill tier.
    pub fn spilled(&self) -> usize {
        self.inner.lock_recover().spill_index.len()
    }

    /// The attached spill file's path, if a spill tier is configured.
    pub fn spill_path(&self) -> Option<PathBuf> {
        self.spill
            .as_ref()
            .map(|s| s.lock_recover().path.clone())
    }

    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock_recover();
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            spill_hits: self.spill_hits.load(Ordering::Relaxed),
            remote_hits: self.remote_hits.load(Ordering::Relaxed),
            entries: inner.map.len(),
            spilled: inner.spill_index.len(),
            bytes: inner.bytes,
            budget_bytes: self.budget_bytes,
        }
    }

    // -- persistence --------------------------------------------------------

    /// Serialize every resident *and spilled* entry to a jsonlite file.
    /// Spilled entries are written first, then residents oldest-first,
    /// so a later [`load`](Self::load) re-inserts them in LRU order
    /// (cold spill content is the first to re-spill under a budget).
    /// Finite f32 payloads survive the text round trip exactly
    /// (shortest-roundtrip float formatting); entries holding
    /// non-finite values are skipped — NaN/inf have no JSON
    /// representation, and writing them would leave a file every later
    /// `load` rejects. A skipped bias simply decomposes again on demand.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let (resident, in_transit, spill_locs) = {
            let inner = self.inner.lock_recover();
            let mut entries: Vec<(&u64, &Entry)> =
                inner.map.iter().collect();
            entries.sort_by_key(|(_, e)| e.stamp);
            let resident: Vec<Json> = entries
                .iter()
                .filter(|(_, e)| entry_is_finite(&e.value))
                .map(|(k, e)| entry_to_json(**k, &e.value))
                .collect();
            // entries mid-flight to the spill file (staged, append not
            // finished) are persisted too — a checkpoint taken in the
            // eviction window must not lose them
            let in_transit: Vec<Json> = inner
                .spilling
                .iter()
                .filter(|(k, _)| !inner.map.contains_key(k))
                .filter(|(_, v)| entry_is_finite(v))
                .map(|(k, v)| entry_to_json(*k, v))
                .collect();
            let spill_locs: Vec<(u64, u64)> =
                inner.spill_index.values().copied().collect();
            (resident, in_transit, spill_locs)
        };
        let mut arr = Vec::with_capacity(
            spill_locs.len() + in_transit.len() + resident.len(),
        );
        for loc in spill_locs {
            if let Some((k, v)) = self.spill_read_at(loc) {
                if entry_is_finite(&v) {
                    arr.push(entry_to_json(k.0, &v));
                }
            }
        }
        arr.extend(in_transit);
        arr.extend(resident);
        let json = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("entries", Json::Arr(arr)),
        ]);
        // atomic replace: a crash mid-write must never leave a
        // truncated file that bricks every later open() on this path
        check_blocking("factorstore::save", &[]);
        let path = path.as_ref();
        let tmp = path
            .with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, json.dump())
            .map_err(|e| anyhow!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path).map_err(|e| {
            anyhow!(
                "rename {} -> {}: {e}",
                tmp.display(),
                path.display()
            )
        })
    }

    /// Load a store previously written by [`save`](Self::save).
    pub fn load(path: impl AsRef<Path>,
                budget_bytes: usize) -> Result<Self> {
        let store = Self::new(budget_bytes);
        store.absorb(path)?;
        Ok(store)
    }

    /// Merge every entry of a store file into this store. Unlike
    /// [`load`](Self::load), this runs on an already-configured store,
    /// so a byte-budgeted store with a spill tier attached spills the
    /// overflow of a large file instead of dropping it.
    pub fn absorb(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        check_blocking("factorstore::absorb", &[]);
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        for entry in json.get("entries").as_arr().unwrap_or(&[]) {
            let (key, value) = entry_from_json(entry)
                .map_err(|e| anyhow!("{}: {e}", path.display()))?;
            self.insert(key, value);
        }
        Ok(())
    }

    /// Load `path` if it exists, else start empty — the CLI's
    /// `--store PATH` semantics.
    pub fn open(path: impl AsRef<Path>,
                budget_bytes: usize) -> Result<Self> {
        if path.as_ref().exists() {
            Self::load(path, budget_bytes)
        } else {
            Ok(Self::new(budget_bytes))
        }
    }
}

/// Append one entry record to the spill file, returning its
/// `(offset, len)` location. Non-finite payloads (no JSON form) and IO
/// failures return `None` — the entry is simply dropped, as before the
/// spill tier existed.
fn spill_append(spill: &Mutex<SpillFile>, key: u64,
                value: &Cached) -> Option<(u64, u64)> {
    // flashlint: allow-fn(io-under-lock) the spill-file lock exists to serialize this seek+append pair; callers hold no other lock here (enforced at runtime by check_blocking)
    if !entry_is_finite(value) {
        return None;
    }
    let text = entry_to_json(key, value).dump();
    let mut f = spill.lock_recover();
    check_blocking("factorstore::spill_append", &["factorstore.spill"]);
    let offset = f.end;
    if f.file.seek(SeekFrom::Start(offset)).is_err() {
        return None;
    }
    if f.file.write_all(text.as_bytes()).is_err() {
        return None;
    }
    if f.file.write_all(b"\n").is_err() {
        // the record may be half-written; advance past it so the next
        // append starts clean, but don't index the torn record
        f.end = offset + text.len() as u64 + 1;
        return None;
    }
    f.end = offset + text.len() as u64 + 1;
    Some((offset, text.len() as u64))
}

/// Whether an entry's payload is fully finite (serializable as JSON
/// numbers). Factors from a corrupt table can carry NaN/inf; those are
/// kept in memory but never persisted.
pub(crate) fn entry_is_finite(value: &Cached) -> bool {
    match value {
        Cached::Factors(f) => {
            f.rel_err.is_finite()
                && f.phi_q.is_finite()
                && f.phi_k.is_finite()
        }
        Cached::Rejected { .. } => true,
    }
}

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn json_to_f32s(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected a number array"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("non-numeric array element"))
        })
        .collect()
}

fn u16s_to_json(xs: &[u16]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn json_to_u16s(j: &Json) -> Result<Vec<u16>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected a bits array"))?
        .iter()
        .map(|v| match v.as_f64() {
            Some(x) if x >= 0.0 && x <= 65535.0 && x.fract() == 0.0 => {
                Ok(x as u16)
            }
            _ => Err(anyhow!("bits element out of u16 range")),
        })
        .collect()
}

fn i8s_to_json(xs: &[i8]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn json_to_i8s(j: &Json) -> Result<Vec<i8>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected an i8 array"))?
        .iter()
        .map(|v| match v.as_f64() {
            Some(x) if (-128.0..=127.0).contains(&x)
                && x.fract() == 0.0 => Ok(x as i8),
            _ => Err(anyhow!("i8 element out of range")),
        })
        .collect()
}

/// Serialize one factor strip's payload into `fields`, prefixing each
/// key with `tag` ("phi_q" / "phi_k"). The f32 layout keeps the legacy
/// field names, so stores written before reduced-precision strips
/// existed load unchanged (and vice versa for f32-only stores).
fn strip_to_json(fields: &mut Vec<(&'static str, Json)>,
                 tag: StripTag, s: &crate::tensor::Strip) {
    // Every caller filters through entry_is_finite (which checks each
    // strip) before serializing — this path runs on live workers, so
    // it must not be able to panic on a payload/dtype mismatch either:
    // matching the payload directly keeps the dispatch total.
    debug_assert!(s.is_finite(), "non-finite strip reached persist");
    match s.payload() {
        StripPayload::F32(xs) => {
            fields.push((tag.plain(), f32s_to_json(xs)))
        }
        StripPayload::Bits16(bits) => {
            fields.push((tag.bits(), u16s_to_json(bits)))
        }
        StripPayload::I8 { data, scales } => {
            fields.push((tag.plain(), i8s_to_json(data)));
            fields.push((tag.scales(), f32s_to_json(scales)));
        }
    }
}

/// Field-name triple for one strip ("phi_q" or "phi_k").
#[derive(Clone, Copy)]
enum StripTag {
    Q,
    K,
}

impl StripTag {
    fn plain(self) -> &'static str {
        match self {
            StripTag::Q => "phi_q",
            StripTag::K => "phi_k",
        }
    }
    fn bits(self) -> &'static str {
        match self {
            StripTag::Q => "phi_q_bits",
            StripTag::K => "phi_k_bits",
        }
    }
    fn scales(self) -> &'static str {
        match self {
            StripTag::Q => "phi_q_scales",
            StripTag::K => "phi_k_scales",
        }
    }
}

/// Deserialize one strip of `rows × cols` at `dtype` from an entry
/// object.
fn strip_from_json(j: &Json, tag: StripTag, dtype: StripDType,
                   rows: usize, cols: usize)
                   -> Result<crate::tensor::Strip> {
    use crate::tensor::Strip;
    let numel = rows * cols;
    let strip = match dtype {
        StripDType::F32 => {
            let d = json_to_f32s(j.get(tag.plain()))?;
            if d.len() != numel {
                return Err(anyhow!(
                    "{} payload {} != {rows}x{cols}", tag.plain(), d.len()
                ));
            }
            Strip::from_f32(Tensor::new(&[rows, cols], d))
        }
        StripDType::Bf16 | StripDType::F16 => {
            let bits = json_to_u16s(j.get(tag.bits()))?;
            if bits.len() != numel {
                return Err(anyhow!(
                    "{} payload {} != {rows}x{cols}", tag.bits(),
                    bits.len()
                ));
            }
            if dtype == StripDType::Bf16 {
                Strip::from_bf16_bits(rows, cols, bits)
            } else {
                Strip::from_f16_bits(rows, cols, bits)
            }
        }
        StripDType::I8 => {
            let data = json_to_i8s(j.get(tag.plain()))?;
            let scales = json_to_f32s(j.get(tag.scales()))?;
            if data.len() != numel || scales.len() != cols {
                return Err(anyhow!(
                    "{} i8 payload {}/{} != {rows}x{cols}", tag.plain(),
                    data.len(), scales.len()
                ));
            }
            Strip::from_i8(rows, cols, data, scales)
        }
    };
    Ok(strip)
}

pub(crate) fn entry_to_json(key: u64, value: &Cached) -> Json {
    // Every caller filters through entry_is_finite first; this is the
    // last line of defense before floats reach a persisted file.
    debug_assert!(entry_is_finite(value), "non-finite factors at {key:#x}");
    let key_hex = format!("{:016x}", key);
    match value {
        Cached::Factors(f) => {
            let mut fields = vec![
                ("key", Json::str(&key_hex)),
                ("kind", Json::str("factors")),
                ("n", Json::num(f.phi_q.rows() as f64)),
                ("m", Json::num(f.phi_k.rows() as f64)),
                ("rank", Json::num(f.rank as f64)),
                ("rel_err", Json::num(f.rel_err as f64)),
                ("dtype", Json::str(f.dtype().name())),
            ];
            strip_to_json(&mut fields, StripTag::Q, &f.phi_q);
            strip_to_json(&mut fields, StripTag::K, &f.phi_k);
            Json::obj(fields)
        }
        Cached::Rejected { measured_rank } => Json::obj(vec![
            ("key", Json::str(&key_hex)),
            ("kind", Json::str("rejected")),
            ("measured_rank", Json::num(*measured_rank as f64)),
        ]),
    }
}

pub(crate) fn entry_from_json(j: &Json) -> Result<(Fingerprint, Cached)> {
    let key_hex = j
        .get("key")
        .as_str()
        .ok_or_else(|| anyhow!("entry without key"))?;
    let key = u64::from_str_radix(key_hex, 16)
        .map_err(|_| anyhow!("bad key {key_hex}"))?;
    let value = match j.get("kind").as_str() {
        Some("factors") => {
            let n = j
                .get("n")
                .as_usize()
                .ok_or_else(|| anyhow!("factors entry without n"))?;
            let m = j
                .get("m")
                .as_usize()
                .ok_or_else(|| anyhow!("factors entry without m"))?;
            let rank = j
                .get("rank")
                .as_usize()
                .ok_or_else(|| anyhow!("factors entry without rank"))?;
            let rel_err = j
                .get("rel_err")
                .as_f64()
                .ok_or_else(|| anyhow!("factors entry without rel_err"))?
                as f32;
            // stores written before reduced-precision strips carry no
            // "dtype" field: those are f32 by construction
            let dtype = match j.get("dtype").as_str() {
                None => StripDType::F32,
                Some(name) => StripDType::parse(name).ok_or_else(|| {
                    anyhow!("unknown strip dtype {name:?}")
                })?,
            };
            let phi_q = strip_from_json(j, StripTag::Q, dtype, n, rank)?;
            let phi_k = strip_from_json(j, StripTag::K, dtype, m, rank)?;
            Cached::Factors(Arc::new(Factors {
                phi_q,
                phi_k,
                rel_err,
                rank,
            }))
        }
        Some("rejected") => Cached::Rejected {
            measured_rank: j
                .get("measured_rank")
                .as_usize()
                .ok_or_else(|| anyhow!("rejected entry without rank"))?,
        },
        other => return Err(anyhow!("unknown entry kind {other:?}")),
    };
    Ok((Fingerprint(key), value))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bias::{Alibi, ExactBias};
    use crate::decompose::from_exact;

    fn cached_alibi(n: usize) -> Cached {
        Cached::Factors(Arc::new(from_exact(&Alibi::new(n, n, 0.5))))
    }

    #[test]
    fn fnv_is_stable_and_order_sensitive() {
        let mut a = Fnv64::new();
        a.write_str("alibi");
        a.write_u64(64);
        let mut b = Fnv64::new();
        b.write_str("alibi");
        b.write_u64(64);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv64::new();
        c.write_u64(64);
        c.write_str("alibi");
        assert_ne!(a.finish(), c.finish());
        // str delimiter: "ab"+"c" != "a"+"bc"
        let mut d = Fnv64::new();
        d.write_str("ab");
        d.write_str("c");
        let mut e = Fnv64::new();
        e.write_str("a");
        e.write_str("bc");
        assert_ne!(d.finish(), e.finish());
    }

    #[test]
    fn get_or_insert_runs_once_then_hits() {
        let store = FactorStore::unbounded();
        let key = Fingerprint(42);
        let mut calls = 0;
        for _ in 0..3 {
            let v = store.get_or_insert_with(key, || {
                calls += 1;
                cached_alibi(8)
            });
            assert!(v.factors().is_some());
        }
        assert_eq!(calls, 1);
        assert_eq!(store.misses(), 1);
        assert_eq!(store.hits(), 2);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        // each rank-2 alibi(8) factor pair: (8 + 8) * 2 * 4 = 128 bytes
        let store = FactorStore::new(300);
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(2), || cached_alibi(8));
        assert_eq!(store.len(), 2);
        // touch key 1 so key 2 is the LRU victim
        assert!(store.get(Fingerprint(1)).is_some());
        store.get_or_insert_with(Fingerprint(3), || cached_alibi(8));
        assert_eq!(store.len(), 2);
        assert!(store.total_bytes() <= 300);
        assert_eq!(store.evictions(), 1);
        assert!(store.get(Fingerprint(1)).is_some());
        assert!(store.get(Fingerprint(2)).is_none(), "LRU must go first");
        assert!(store.get(Fingerprint(3)).is_some());
    }

    #[test]
    fn oversized_entry_is_never_self_evicted() {
        // a rank-2 alibi(8) entry is 128 bytes — more than this whole
        // budget; it used to evict itself right after insertion, so
        // every later plan re-ran the decomposition (silent thrash)
        let store = FactorStore::new(64);
        let mut calls = 0;
        for _ in 0..3 {
            store.get_or_insert_with(Fingerprint(5), || {
                calls += 1;
                cached_alibi(8)
            });
        }
        assert_eq!(calls, 1, "oversized entry must stay resident");
        assert_eq!(store.evictions(), 0);
        assert!(store.get(Fingerprint(5)).is_some());
        // a later insert displaces it (dropped — no spill configured)
        store.get_or_insert_with(Fingerprint(6), || cached_alibi(8));
        assert_eq!(store.evictions(), 1);
        assert!(store.get(Fingerprint(6)).is_some());
    }

    #[test]
    fn spill_tier_reloads_evicted_entries() {
        let path = std::env::temp_dir().join(format!(
            "fb_spill_unit_{}.jsonl",
            std::process::id()
        ));
        // budget holds two 128-byte entries
        let store = FactorStore::new(300).spill_to(&path).expect("spill");
        let original = cached_alibi(8);
        store.get_or_insert_with(Fingerprint(1), || original.clone());
        store.get_or_insert_with(Fingerprint(2), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(3), || cached_alibi(8));
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.spilled(), 1, "evicted entry moved to spill");
        // key 1 reloads from disk — one read, not a new decomposition
        let mut calls = 0;
        let back = store.get_or_insert_with(Fingerprint(1), || {
            calls += 1;
            cached_alibi(8)
        });
        assert_eq!(calls, 0, "spill hit must not re-decompose");
        assert_eq!(store.spill_hits(), 1);
        assert_eq!(store.misses(), 3);
        let (of, bf) = (
            original.factors().unwrap(),
            back.factors().unwrap(),
        );
        assert_eq!(of.phi_q, bf.phi_q,
                   "spill round trip must be exact");
        assert_eq!(of.phi_k, bf.phi_k);
        // reloading key 1 displaced another entry into the spill
        assert_eq!(store.spilled(), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn plain_get_falls_back_to_spill() {
        let path = std::env::temp_dir().join(format!(
            "fb_spill_get_{}.jsonl",
            std::process::id()
        ));
        let store = FactorStore::new(150).spill_to(&path).expect("spill");
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(2), || cached_alibi(8));
        assert_eq!(store.spilled(), 1);
        assert!(store.get(Fingerprint(1)).is_some(),
                "get must reload from spill");
        assert_eq!(store.spill_hits(), 1);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_includes_spilled_entries() {
        let spill = std::env::temp_dir().join(format!(
            "fb_spill_save_{}.jsonl",
            std::process::id()
        ));
        let store =
            FactorStore::new(150).spill_to(&spill).expect("spill");
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(2), || cached_alibi(8));
        assert_eq!((store.len(), store.spilled()), (1, 1));
        let path = std::env::temp_dir().join(format!(
            "fb_store_spillsave_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let loaded = FactorStore::load(&path, usize::MAX).expect("load");
        assert_eq!(loaded.len(), 2,
                   "save must persist the spill tier too");
        assert!(loaded.get(Fingerprint(1)).is_some());
        assert!(loaded.get(Fingerprint(2)).is_some());
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(spill);
    }

    #[test]
    fn rejected_entries_are_tiny_and_cacheable() {
        let store = FactorStore::new(64);
        store.get_or_insert_with(Fingerprint(9), || Cached::Rejected {
            measured_rank: 57,
        });
        match store.get(Fingerprint(9)) {
            Some(Cached::Rejected { measured_rank }) => {
                assert_eq!(measured_rank, 57)
            }
            other => panic!("expected rejected entry, got {other:?}"),
        }
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let store = FactorStore::unbounded();
        store.get_or_insert_with(Fingerprint(7), || cached_alibi(12));
        store.get_or_insert_with(Fingerprint(8), || Cached::Rejected {
            measured_rank: 33,
        });
        let path = std::env::temp_dir().join(format!(
            "fb_store_unit_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let loaded = FactorStore::load(&path, usize::MAX).expect("load");
        assert_eq!(loaded.len(), 2);
        let orig = store.get(Fingerprint(7)).unwrap();
        let back = loaded.get(Fingerprint(7)).unwrap();
        let (of, bf) = (orig.factors().unwrap(), back.factors().unwrap());
        assert_eq!(of.rank, bf.rank);
        assert_eq!(of.phi_q, bf.phi_q);
        assert_eq!(of.phi_k, bf.phi_k);
        assert!(matches!(
            loaded.get(Fingerprint(8)),
            Some(Cached::Rejected { measured_rank: 33 })
        ));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_load_preserves_reduced_precision_dtypes() {
        use crate::decompose::quantize_factors;
        use crate::tensor::StripDType;
        let store = FactorStore::unbounded();
        let base = from_exact(&Alibi::new(10, 10, 0.25));
        for (i, dtype) in [StripDType::F32, StripDType::Bf16,
                           StripDType::F16, StripDType::I8]
            .into_iter()
            .enumerate()
        {
            let (qf, _) = quantize_factors(&base, dtype);
            store.insert(Fingerprint(i as u64),
                         Cached::Factors(Arc::new(qf)));
        }
        let path = std::env::temp_dir().join(format!(
            "fb_store_dtype_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let loaded = FactorStore::load(&path, usize::MAX).expect("load");
        for (i, dtype) in [StripDType::F32, StripDType::Bf16,
                           StripDType::F16, StripDType::I8]
            .into_iter()
            .enumerate()
        {
            let orig = store.get(Fingerprint(i as u64)).unwrap();
            let back = loaded.get(Fingerprint(i as u64)).unwrap();
            let (of, bf) =
                (orig.factors().unwrap(), back.factors().unwrap());
            assert_eq!(bf.dtype(), dtype, "dtype survives persistence");
            assert_eq!(of.phi_q, bf.phi_q,
                       "{dtype:?} payload round trip must be bit-exact");
            assert_eq!(of.phi_k, bf.phi_k);
            assert_eq!(of.rel_err, bf.rel_err);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_skips_non_finite_entries_so_load_never_bricks() {
        let store = FactorStore::unbounded();
        store.insert(Fingerprint(1), cached_alibi(8));
        store.insert(
            Fingerprint(2),
            Cached::Factors(Arc::new(Factors::from_tensors(
                Tensor::new(&[2, 1], vec![f32::NAN, 1.0]),
                Tensor::new(&[2, 1], vec![0.5, 2.0]),
                0.0,
                1,
            ))),
        );
        let path = std::env::temp_dir().join(format!(
            "fb_store_nan_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let loaded =
            FactorStore::load(&path, usize::MAX).expect("load succeeds");
        assert_eq!(loaded.len(), 1, "NaN entry must be skipped");
        assert!(loaded.get(Fingerprint(1)).is_some());
        assert!(loaded.get(Fingerprint(2)).is_none());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn absorb_under_budget_spills_overflow_instead_of_dropping() {
        let store = FactorStore::unbounded();
        store.insert(Fingerprint(1), cached_alibi(8));
        store.insert(Fingerprint(2), cached_alibi(8));
        store.insert(Fingerprint(3), cached_alibi(8));
        let path = std::env::temp_dir().join(format!(
            "fb_absorb_{}.json",
            std::process::id()
        ));
        store.save(&path).expect("save");
        let spill = std::env::temp_dir().join(format!(
            "fb_absorb_spill_{}.jsonl",
            std::process::id()
        ));
        // budget holds one 128-byte entry; the other two must land in
        // the spill tier, not on the floor
        let budgeted =
            FactorStore::new(150).spill_to(&spill).expect("spill");
        budgeted.absorb(&path).expect("absorb");
        assert_eq!(budgeted.len() + budgeted.spilled(), 3,
                   "a budgeted load must not drop entries");
        for k in [1u64, 2, 3] {
            assert!(budgeted.get(Fingerprint(k)).is_some(),
                    "key {k} must be reachable");
        }
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(spill);
    }

    #[test]
    fn open_missing_path_starts_empty() {
        let path = std::env::temp_dir().join(format!(
            "fb_store_missing_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let store = FactorStore::open(&path, usize::MAX).expect("open");
        assert!(store.is_empty());
    }

    #[test]
    fn stats_snapshot_and_summary() {
        let store = FactorStore::new(1 << 20);
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        store.get_or_insert_with(Fingerprint(1), || cached_alibi(8));
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.bytes > 0);
        assert!(s.summary().contains("hits=1"));
        assert_eq!(s.to_json().get("misses").as_usize(), Some(1));
    }
}
