//! Dense row-major f32 tensor — the host-side numeric substrate.
//!
//! Everything the reference attention, bias generators, SVD and the
//! coordinator's host math need: matmul (blocked + transposed-B
//! microkernel), transpose, softmax, concat, slicing, reductions and
//! elementwise ops. Shapes are `Vec<usize>`; rank ≤ 4 in practice
//! (head, row, col).

use std::fmt;

pub mod kv;
pub mod strip;

pub use kv::KvCache;
pub use strip::{
    bf16_to_f32, f16_to_f32, f32_to_bf16, f32_to_f16, Strip, StripDType,
    StripPayload,
};

#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// Zero-copy 2-D view into a tensor's storage — the tile currency of the
/// kernel engine. Row-range and slab views cost a slice borrow, never a
/// copy, so per-tile access stays allocation-free.
#[derive(Clone, Copy, Debug)]
pub struct View2<'a> {
    pub rows: usize,
    pub cols: usize,
    data: &'a [f32],
}

impl<'a> View2<'a> {
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> Self {
        assert_eq!(rows * cols, data.len(), "view shape mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Rows `[start, stop)` as a narrower view (cheap tile slicing).
    pub fn rows_view(&self, start: usize, stop: usize) -> View2<'a> {
        View2::new(
            stop - start,
            self.cols,
            &self.data[start * self.cols..stop * self.cols],
        )
    }

    /// Materialize the view (test/inspection path).
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(&[self.rows, self.cols], self.data.to_vec())
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    // ---- constructors -----------------------------------------------------

    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with {} elements",
            shape,
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self::new(shape, vec![0.0; shape.iter().product()])
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self::new(shape, vec![1.0; shape.iter().product()])
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self::new(shape, vec![v; shape.iter().product()])
    }

    /// `[0, 1, ..., n-1]` as a 1-D tensor.
    pub fn arange(n: usize) -> Self {
        Self::new(&[n], (0..n).map(|i| i as f32).collect())
    }

    pub fn from_fn(shape: &[usize], f: impl Fn(&[usize]) -> f32) -> Self {
        let numel: usize = shape.iter().product();
        let mut idx = vec![0usize; shape.len()];
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(f(&idx));
            for d in (0..shape.len()).rev() {
                idx[d] += 1;
                if idx[d] < shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        Self::new(shape, data)
    }

    pub fn randn(shape: &[usize], scale: f32,
                 rng: &mut crate::util::Xoshiro256) -> Self {
        let numel = shape.iter().product();
        Self::new(shape, rng.normal_vec(numel, scale))
    }

    // ---- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Size in bytes (f32).
    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.rank(), 2);
        let m = self.shape[1];
        &self.data[i * m..(i + 1) * m]
    }

    /// Zero-copy 2-D view of this rank-2 tensor.
    pub fn view2(&self) -> View2<'_> {
        assert_eq!(self.rank(), 2, "view2 needs a rank-2 tensor");
        View2::new(self.shape[0], self.shape[1], &self.data)
    }

    /// Zero-copy view of rows `[start, stop)` of a rank-2 tensor.
    pub fn view_rows(&self, start: usize, stop: usize) -> View2<'_> {
        assert_eq!(self.rank(), 2, "view_rows needs a rank-2 tensor");
        let m = self.shape[1];
        View2::new(stop - start, m, &self.data[start * m..stop * m])
    }

    /// Zero-copy 2-D view of slab `p` of a `(..., R, C)` tensor whose
    /// leading dims are flattened: slab `p` is `data[p·R·C .. (p+1)·R·C]`
    /// viewed as `(R, C)`. For a rank-2 tensor, slab 0 is the whole
    /// tensor.
    pub fn view_slab(&self, p: usize) -> View2<'_> {
        assert!(self.rank() >= 2, "view_slab needs rank ≥ 2");
        let r = self.shape[self.rank() - 2];
        let c = self.shape[self.rank() - 1];
        let sub = r * c;
        View2::new(r, c, &self.data[p * sub..(p + 1) * sub])
    }

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        Tensor::new(shape, self.data.clone())
    }

    /// 3-D indexing helper: slice `[h]` of an (H, N, M) tensor as (N, M).
    pub fn index0(&self, h: usize) -> Tensor {
        assert!(self.rank() >= 2);
        let sub: usize = self.shape[1..].iter().product();
        Tensor::new(
            &self.shape[1..],
            self.data[h * sub..(h + 1) * sub].to_vec(),
        )
    }

    // ---- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::new(&self.shape, self.data.iter().map(|&x| f(x)).collect())
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        Tensor::new(
            &self.shape,
            self.data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        )
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    // ---- reductions --------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
            as f32
    }

    /// Relative L2 distance ‖a − b‖ / ‖b‖.
    pub fn rel_err(&self, other: &Tensor) -> f32 {
        let diff = self.sub(other).norm() as f64;
        let denom = (other.norm() as f64).max(1e-30);
        (diff / denom) as f32
    }

    /// Row-wise mean of a 2-D tensor → 1-D (N,).
    pub fn mean_rows(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (n, m) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0; n];
        for i in 0..n {
            out[i] = self.row(i).iter().sum::<f32>() / m as f32;
        }
        Tensor::new(&[n], out)
    }

    // ---- linear algebra ----------------------------------------------------

    /// 2-D transpose.
    pub fn t(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (n, m) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; n * m];
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..n).step_by(B) {
            for jb in (0..m).step_by(B) {
                for i in ib..(ib + B).min(n) {
                    for j in jb..(jb + B).min(m) {
                        out[j * n + i] = self.data[i * m + j];
                    }
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Dense matmul C = A·B for 2-D tensors, blocked over K with an
    /// i-k-j loop order (unit-stride inner loop; autovectorizes well).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (n, k) = (self.shape[0], self.shape[1]);
        let (k2, m) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; n * m];
        let a = &self.data;
        let b = &other.data;
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &b[kk * m..(kk + 1) * m];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += aik * bv;
                }
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// C = A·Bᵀ without materializing the transpose (dot-product kernel;
    /// the attention score path).
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        let (n, k) = (self.shape[0], self.shape[1]);
        let (m, k2) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul_t inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &self.data[i * k..(i + 1) * k];
            for j in 0..m {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&x, &y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                out[i * m + j] = acc;
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Row-wise numerically-stable softmax of a 2-D tensor.
    pub fn softmax_rows(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (n, m) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let row = self.row(i);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            let orow = &mut out[i * m..(i + 1) * m];
            let mut sum = 0.0f32;
            for (o, &x) in orow.iter_mut().zip(row) {
                let e = (x - mx).exp();
                *o = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
        Tensor::new(&[n, m], out)
    }

    /// Concatenate along the last axis (2-D only): (N, A) ++ (N, B) → (N, A+B).
    pub fn concat_cols(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(other.rank(), 2);
        assert_eq!(self.shape[0], other.shape[0], "concat row mismatch");
        let (n, a, b) = (self.shape[0], self.shape[1], other.shape[1]);
        let mut out = Vec::with_capacity(n * (a + b));
        for i in 0..n {
            out.extend_from_slice(self.row(i));
            out.extend_from_slice(other.row(i));
        }
        Tensor::new(&[n, a + b], out)
    }

    /// Row slice of a 2-D tensor: rows [start, stop).
    pub fn slice_rows(&self, start: usize, stop: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let m = self.shape[1];
        Tensor::new(
            &[stop - start, m],
            self.data[start * m..stop * m].to_vec(),
        )
    }

    /// Column slice of a 2-D tensor: cols [start, stop).
    pub fn slice_cols(&self, start: usize, stop: usize) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (n, m) = (self.shape[0], self.shape[1]);
        let w = stop - start;
        let mut out = Vec::with_capacity(n * w);
        for i in 0..n {
            out.extend_from_slice(&self.data[i * m + start..i * m + stop]);
        }
        Tensor::new(&[n, w], out)
    }

    /// Stack equal-shape tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let shape = parts[0].shape().to_vec();
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for p in parts {
            assert_eq!(p.shape(), &shape[..], "stack shape mismatch");
            data.extend_from_slice(&p.data);
        }
        let mut out_shape = vec![parts.len()];
        out_shape.extend_from_slice(&shape);
        Tensor::new(&out_shape, data)
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Tensor {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.set2(i, i, 1.0);
        }
        t
    }

    /// All-close comparison with absolute + relative tolerance.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(&a, &b)| {
            (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn from_fn_row_major_order() {
        let t = Tensor::from_fn(&[2, 3], |ix| (ix[0] * 10 + ix[1]) as f32);
        assert_eq!(t.data(), &[0., 1., 2., 10., 11., 12.]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Xoshiro256::new(0);
        let a = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let out = a.matmul(&Tensor::eye(7));
        assert!(out.allclose(&a, 1e-6, 1e-6));
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Xoshiro256::new(1);
        let a = Tensor::randn(&[9, 4], 1.0, &mut rng);
        let b = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let got = a.matmul_t(&b);
        let expect = a.matmul(&b.t());
        assert!(got.allclose(&expect, 1e-5, 1e-5));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::new(2);
        let a = Tensor::randn(&[37, 53], 1.0, &mut rng);
        assert!(a.t().t().allclose(&a, 0.0, 0.0));
    }

    #[test]
    fn softmax_rows_normalized_and_stable() {
        let t = Tensor::new(&[2, 3], vec![1e4, 1e4, 1e4, 0., 1., 2.]);
        let s = t.softmax_rows();
        for i in 0..2 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        assert!((s.at2(0, 0) - 1.0 / 3.0).abs() < 1e-6);
        assert!(s.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn concat_and_slice_roundtrip() {
        let mut rng = Xoshiro256::new(3);
        let a = Tensor::randn(&[4, 3], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let c = a.concat_cols(&b);
        assert_eq!(c.shape(), &[4, 8]);
        assert!(c.slice_cols(0, 3).allclose(&a, 0.0, 0.0));
        assert!(c.slice_cols(3, 8).allclose(&b, 0.0, 0.0));
    }

    #[test]
    fn slice_rows_works() {
        let t = Tensor::from_fn(&[5, 2], |ix| ix[0] as f32);
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[1., 1., 2., 2.]);
    }

    #[test]
    fn stack_and_index0() {
        let a = Tensor::ones(&[2, 2]);
        let b = Tensor::zeros(&[2, 2]);
        let s = Tensor::stack(&[a.clone(), b.clone()]);
        assert_eq!(s.shape(), &[2, 2, 2]);
        assert!(s.index0(0).allclose(&a, 0.0, 0.0));
        assert!(s.index0(1).allclose(&b, 0.0, 0.0));
    }

    #[test]
    fn norms_and_errors() {
        let a = Tensor::new(&[1, 2], vec![3., 4.]);
        assert!((a.norm() - 5.0).abs() < 1e-6);
        let b = Tensor::new(&[1, 2], vec![3., 5.]);
        assert!((a.rel_err(&b) - 1.0 / (34f32).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn mean_rows() {
        let t = Tensor::new(&[2, 2], vec![1., 3., 5., 7.]);
        assert_eq!(t.mean_rows().data(), &[2., 6.]);
    }

    #[test]
    fn arange_and_map() {
        let t = Tensor::arange(4).map(|x| x * x);
        assert_eq!(t.data(), &[0., 1., 4., 9.]);
    }

    #[test]
    fn view2_and_row_ranges() {
        let t = Tensor::from_fn(&[5, 3], |ix| (ix[0] * 10 + ix[1]) as f32);
        let v = t.view2();
        assert_eq!((v.rows, v.cols), (5, 3));
        assert_eq!(v.row(2), &[20., 21., 22.]);
        assert_eq!(v.at(4, 1), 41.0);
        let r = t.view_rows(1, 4);
        assert_eq!(r.rows, 3);
        assert_eq!(r.row(0), t.row(1));
        let rr = v.rows_view(2, 5);
        assert_eq!(rr.row(0), t.row(2));
        assert!(r.to_tensor().allclose(&t.slice_rows(1, 4), 0.0, 0.0));
    }

    #[test]
    fn view_slab_matches_index0() {
        let mut rng = Xoshiro256::new(5);
        let t = Tensor::randn(&[2, 3, 4, 5], 1.0, &mut rng);
        for p in 0..6 {
            let slab = t.view_slab(p).to_tensor();
            // flattened (2, 3) leading dims: slab p == reshaped index
            let flat = t.reshape(&[6, 4, 5]).index0(p);
            assert!(slab.allclose(&flat, 0.0, 0.0), "slab {p}");
        }
        let t2 = Tensor::from_fn(&[3, 2], |ix| ix[0] as f32);
        assert!(t2.view_slab(0).to_tensor().allclose(&t2, 0.0, 0.0));
    }

    #[test]
    fn matmul_associativity_with_vectors() {
        let mut rng = Xoshiro256::new(4);
        let a = Tensor::randn(&[8, 6], 0.5, &mut rng);
        let b = Tensor::randn(&[6, 7], 0.5, &mut rng);
        let c = Tensor::randn(&[7, 3], 0.5, &mut rng);
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.allclose(&right, 1e-4, 1e-4));
    }
}
