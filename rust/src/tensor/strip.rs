//! Reduced-precision factor-strip storage.
//!
//! Low-rank factor strips are the safest place in the system to
//! quantize: the Eckart–Young machinery already bounds the bias error,
//! and a strip element only ever enters the kernel through the f32
//! accumulator of the Eq. (3) tile contraction. A [`Strip`] is a
//! 2-D `(rows × cols)` factor matrix stored at a [`StripDType`]:
//!
//! * [`StripDType::F32`] — exact, the legacy representation (zero-copy
//!   view into the kernel).
//! * [`StripDType::Bf16`] — top 16 bits of the f32 (round to nearest
//!   even); same dynamic range, ~3 decimal digits. Halves bytes.
//! * [`StripDType::F16`] — IEEE binary16 (round to nearest even,
//!   overflow → ±inf, |x| < 2⁻²⁵ flushes to ±0). Halves bytes with
//!   more mantissa but less range than bf16.
//! * [`StripDType::I8`] — experimental: symmetric per-column scales
//!   (`scale[c] = max|col| / 127`). Quarter bytes.
//!
//! Quantization is *storage-only*: every consumer decodes back to f32
//! before arithmetic ([`Strip::row_into`] / [`Strip::to_tensor`]), so
//! the kernel numerics stay f32 and the error is exactly the
//! representation error measured by
//! [`crate::decompose::quantize_factors`].

use super::{Tensor, View2};

/// Element type of a stored factor strip.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StripDType {
    /// Exact f32 (legacy representation).
    F32,
    /// bfloat16: f32 with the low 16 mantissa bits dropped.
    Bf16,
    /// IEEE binary16.
    F16,
    /// Experimental: int8 with symmetric per-column f32 scales.
    I8,
}

impl StripDType {
    /// Stored bytes per element (I8 excludes the per-column scale
    /// overhead, which [`Strip::size_bytes`] accounts separately).
    pub fn size_bytes(self) -> usize {
        match self {
            StripDType::F32 => 4,
            StripDType::Bf16 | StripDType::F16 => 2,
            StripDType::I8 => 1,
        }
    }

    /// Canonical lowercase name (used by persistence and CLI flags).
    pub fn name(self) -> &'static str {
        match self {
            StripDType::F32 => "f32",
            StripDType::Bf16 => "bf16",
            StripDType::F16 => "f16",
            StripDType::I8 => "i8",
        }
    }

    /// Parse a [`Self::name`] string.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(StripDType::F32),
            "bf16" => Some(StripDType::Bf16),
            "f16" => Some(StripDType::F16),
            "i8" => Some(StripDType::I8),
            _ => None,
        }
    }
}

impl std::fmt::Display for StripDType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------------
// Scalar conversions (pub: the property tests and persistence use them)
// ---------------------------------------------------------------------------

/// f32 → bf16 bits, round to nearest even. NaN stays NaN (quieted).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// bf16 bits → f32 (exact).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// f32 → IEEE binary16 bits, round to nearest even. Overflow → ±inf,
/// |x| < 2⁻²⁵ flushes to ±0, NaN stays NaN (quieted).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // inf / NaN: keep the top mantissa bits, force quiet on NaN
        let payload = (man >> 13) as u16 & 0x03FF;
        let quiet = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | quiet | payload;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow → inf
    }
    if unbiased < -25 {
        return sign; // underflow → signed zero
    }
    let mant = man | 0x0080_0000; // implicit leading 1
    // normals shift 13; subnormals shift more as the exponent drops
    let shift = if unbiased >= -14 {
        13u32
    } else {
        (13 + (-14 - unbiased)) as u32
    };
    let halfway = 1u32 << (shift - 1);
    let rem = mant & ((1u32 << shift) - 1);
    let mut m = mant >> shift;
    if rem > halfway || (rem == halfway && (m & 1) == 1) {
        m += 1; // round up (carry may bump into the exponent — correct)
    }
    if unbiased >= -14 {
        // m ∈ [2¹⁰, 2¹¹]; subtracting the implicit bit and adding the
        // biased exponent lets a carry propagate into the exponent
        let e = (unbiased + 15) as u32;
        sign | ((e << 10) + (m - (1 << 10))) as u16
    } else {
        // subnormal; a carry to 2¹⁰ is exactly the smallest normal
        sign | m as u16
    }
}

/// IEEE binary16 bits → f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign_bits = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    match exp {
        0 => {
            // ±0 and subnormals: value = man · 2⁻²⁴ (exact in f32)
            let mag = man as f32 * f32::from_bits(0x3380_0000); // 2⁻²⁴
            if sign_bits != 0 {
                -mag
            } else {
                f32::from_bits(sign_bits | mag.to_bits())
            }
        }
        0x1F => f32::from_bits(sign_bits | 0x7F80_0000 | (man << 13)),
        _ => {
            let e = exp as u32 + 112; // rebias 15 → 127
            f32::from_bits(sign_bits | (e << 23) | (man << 13))
        }
    }
}

// ---------------------------------------------------------------------------
// Strip
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum StripData {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    F16(Vec<u16>),
    I8 {
        data: Vec<i8>,
        /// One symmetric scale per column (`cols` entries).
        scales: Vec<f32>,
    },
}

/// Borrowed view of a strip's raw payload (see [`Strip::payload`]).
/// bf16 and f16 share the `Bits16` variant: their wire form is the
/// same `Vec<u16>`, and the field name the serializer needs is keyed
/// off [`Strip::dtype`] anyway.
#[derive(Clone, Copy, Debug)]
pub enum StripPayload<'a> {
    F32(&'a [f32]),
    Bits16(&'a [u16]),
    I8 { data: &'a [i8], scales: &'a [f32] },
}

/// A `(rows × cols)` factor matrix at a reduced-precision storage
/// dtype. Row-major; every accessor decodes to f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Strip {
    rows: usize,
    cols: usize,
    data: StripData,
}

impl Strip {
    /// Wrap an exact f32 matrix (no copy, no precision change).
    pub fn from_f32(t: Tensor) -> Self {
        assert_eq!(t.rank(), 2, "strips are 2-D");
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        Self {
            rows,
            cols,
            data: StripData::F32(t.into_data()),
        }
    }

    /// Quantize an f32 matrix to `dtype`.
    pub fn quantize(t: &Tensor, dtype: StripDType) -> Self {
        assert_eq!(t.rank(), 2, "strips are 2-D");
        let (rows, cols) = (t.shape()[0], t.shape()[1]);
        let data = match dtype {
            StripDType::F32 => StripData::F32(t.data().to_vec()),
            StripDType::Bf16 => StripData::Bf16(
                t.data().iter().map(|&x| f32_to_bf16(x)).collect(),
            ),
            StripDType::F16 => StripData::F16(
                t.data().iter().map(|&x| f32_to_f16(x)).collect(),
            ),
            StripDType::I8 => {
                let mut scales = vec![0.0f32; cols];
                for r in 0..rows {
                    for (c, s) in scales.iter_mut().enumerate() {
                        *s = s.max(t.data()[r * cols + c].abs());
                    }
                }
                for s in scales.iter_mut() {
                    *s /= 127.0;
                }
                let mut data = Vec::with_capacity(rows * cols);
                for r in 0..rows {
                    for (c, &s) in scales.iter().enumerate() {
                        let x = t.data()[r * cols + c];
                        let q = if s > 0.0 {
                            (x / s).round().clamp(-127.0, 127.0) as i8
                        } else {
                            0
                        };
                        data.push(q);
                    }
                }
                StripData::I8 { data, scales }
            }
        };
        Self { rows, cols, data }
    }

    /// Rebuild a bf16 strip from raw bits (persistence).
    pub fn from_bf16_bits(rows: usize, cols: usize,
                          bits: Vec<u16>) -> Self {
        assert_eq!(bits.len(), rows * cols, "bf16 strip length");
        Self {
            rows,
            cols,
            data: StripData::Bf16(bits),
        }
    }

    /// Rebuild an f16 strip from raw bits (persistence).
    pub fn from_f16_bits(rows: usize, cols: usize,
                         bits: Vec<u16>) -> Self {
        assert_eq!(bits.len(), rows * cols, "f16 strip length");
        Self {
            rows,
            cols,
            data: StripData::F16(bits),
        }
    }

    /// Rebuild an i8 strip from raw data + per-column scales
    /// (persistence).
    pub fn from_i8(rows: usize, cols: usize, data: Vec<i8>,
                   scales: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "i8 strip length");
        assert_eq!(scales.len(), cols, "i8 scales length");
        Self {
            rows,
            cols,
            data: StripData::I8 { data, scales },
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `[rows, cols]` (mirrors `Tensor::shape()` for 2-D).
    pub fn shape(&self) -> [usize; 2] {
        [self.rows, self.cols]
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    pub fn dtype(&self) -> StripDType {
        match &self.data {
            StripData::F32(_) => StripDType::F32,
            StripData::Bf16(_) => StripDType::Bf16,
            StripData::F16(_) => StripDType::F16,
            StripData::I8 { .. } => StripDType::I8,
        }
    }

    /// Stored payload bytes: `numel · dtype width`, plus the per-column
    /// scale table for i8. This is what the `FactorStore` byte budget
    /// and the Thm 3.2 storage accounting see.
    pub fn size_bytes(&self) -> usize {
        match &self.data {
            StripData::F32(d) => d.len() * 4,
            StripData::Bf16(d) | StripData::F16(d) => d.len() * 2,
            StripData::I8 { data, scales } => {
                data.len() + scales.len() * 4
            }
        }
    }

    /// Zero-copy f32 view — `Some` only for [`StripDType::F32`] (the
    /// kernel's fast path).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.data {
            StripData::F32(d) => Some(d),
            _ => None,
        }
    }

    /// Zero-copy 2-D view — `Some` only for [`StripDType::F32`].
    pub fn as_view2(&self) -> Option<View2<'_>> {
        self.as_f32()
            .map(|d| View2::new(self.rows, self.cols, d))
    }

    /// Raw 16-bit payload — `Some` for bf16/f16 (persistence).
    pub fn bits_u16(&self) -> Option<&[u16]> {
        match &self.data {
            StripData::Bf16(d) | StripData::F16(d) => Some(d),
            _ => None,
        }
    }

    /// Raw i8 payload + per-column scales (persistence).
    pub fn i8_parts(&self) -> Option<(&[i8], &[f32])> {
        match &self.data {
            StripData::I8 { data, scales } => Some((data, scales)),
            _ => None,
        }
    }

    /// Borrowed raw payload, one variant per storage class — lets
    /// persistence match exhaustively instead of re-deriving the
    /// variant from [`Self::dtype`] and unwrapping `Option` accessors.
    pub fn payload(&self) -> StripPayload<'_> {
        match &self.data {
            StripData::F32(d) => StripPayload::F32(d),
            StripData::Bf16(d) | StripData::F16(d) => {
                StripPayload::Bits16(d)
            }
            StripData::I8 { data, scales } => {
                StripPayload::I8 { data, scales }
            }
        }
    }

    /// Decode row `i` into `out[..cols]`.
    pub fn row_into(&self, i: usize, out: &mut [f32]) {
        let (lo, hi) = (i * self.cols, (i + 1) * self.cols);
        let out = &mut out[..self.cols];
        match &self.data {
            StripData::F32(d) => out.copy_from_slice(&d[lo..hi]),
            StripData::Bf16(d) => {
                for (o, &b) in out.iter_mut().zip(&d[lo..hi]) {
                    *o = bf16_to_f32(b);
                }
            }
            StripData::F16(d) => {
                for (o, &b) in out.iter_mut().zip(&d[lo..hi]) {
                    *o = f16_to_f32(b);
                }
            }
            StripData::I8 { data, scales } => {
                for ((o, &q), &s) in
                    out.iter_mut().zip(&data[lo..hi]).zip(scales)
                {
                    *o = q as f32 * s;
                }
            }
        }
    }

    /// Decode the whole strip to a dense f32 tensor.
    pub fn to_tensor(&self) -> Tensor {
        let mut data = vec![0.0f32; self.numel()];
        for i in 0..self.rows {
            self.row_into(i, &mut data[i * self.cols..(i + 1) * self.cols]);
        }
        Tensor::new(&[self.rows, self.cols], data)
    }

    /// Whether every decoded element is finite (persistence guard —
    /// f16 overflow and quantizing non-finite inputs can produce ±inf).
    pub fn is_finite(&self) -> bool {
        match &self.data {
            StripData::F32(d) => d.iter().all(|x| x.is_finite()),
            StripData::Bf16(d) => {
                d.iter().all(|&b| bf16_to_f32(b).is_finite())
            }
            StripData::F16(d) => {
                d.iter().all(|&b| f16_to_f32(b).is_finite())
            }
            StripData::I8 { scales, .. } => {
                scales.iter().all(|s| s.is_finite())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn bf16_round_trip_of_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -1.5, 0.5, 256.0, 3.0e38, -1.0e-30] {
            let y = bf16_to_f32(f32_to_bf16(x));
            let back = bf16_to_f32(f32_to_bf16(y));
            assert_eq!(y.to_bits(), back.to_bits(), "x={x}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // low half exactly 0x8000 is halfway; ties-to-even keeps the
        // even bf16 0x3F80
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8000)), 0x3F80);
        // just above halfway rounds up
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        // a tie sitting on an odd bf16 rounds up to the even one
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);
    }

    #[test]
    fn f16_round_trip_of_representable_values() {
        for x in [0.0f32, -0.0, 1.0, -2.0, 0.099975586, 65504.0,
                  6.1035156e-5, 5.9604645e-8] {
            let h = f32_to_f16(x);
            let y = f16_to_f32(h);
            assert_eq!(f32_to_f16(y), h, "x={x}");
            let back = f16_to_f32(f32_to_f16(y));
            assert_eq!(y.to_bits(), back.to_bits(), "x={x}");
        }
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16(1.0e6), 0x7C00, "overflow → +inf");
        assert_eq!(f32_to_f16(-1.0e6), 0xFC00, "overflow → -inf");
        assert_eq!(f32_to_f16(1.0e-9), 0x0000, "underflow → +0");
    }

    #[test]
    fn f16_relative_error_within_half_ulp() {
        let mut rng = Xoshiro256::new(11);
        let t = Tensor::randn(&[64, 8], 1.0, &mut rng);
        for &x in t.data() {
            let y = f16_to_f32(f32_to_f16(x));
            // binary16 has 11 significand bits → half-ulp 2⁻¹²
            assert!((y - x).abs() <= x.abs() * (1.0 / 4096.0) + 1e-7,
                    "x={x} y={y}");
        }
    }

    #[test]
    fn strip_round_trip_and_bytes() {
        let mut rng = Xoshiro256::new(12);
        let t = Tensor::randn(&[10, 3], 1.0, &mut rng);
        let f = Strip::from_f32(t.clone());
        assert_eq!(f.dtype(), StripDType::F32);
        assert_eq!(f.size_bytes(), 120);
        assert_eq!(f.to_tensor().data(), t.data());
        assert_eq!(f.as_f32().map(|d| d.len()), Some(30));

        let b = Strip::quantize(&t, StripDType::Bf16);
        assert_eq!(b.size_bytes(), 60);
        assert!(b.as_f32().is_none());
        assert!(b.to_tensor().allclose(&t, 1e-2, 1e-2));

        let i = Strip::quantize(&t, StripDType::I8);
        assert_eq!(i.size_bytes(), 30 + 12);
        assert!(i.to_tensor().allclose(&t, 0.05, 0.05));
    }

    #[test]
    fn strip_row_into_matches_to_tensor() {
        let mut rng = Xoshiro256::new(13);
        let t = Tensor::randn(&[7, 5], 2.0, &mut rng);
        for dtype in [StripDType::F32, StripDType::Bf16, StripDType::F16,
                      StripDType::I8] {
            let s = Strip::quantize(&t, dtype);
            let dense = s.to_tensor();
            let mut row = vec![0.0f32; 5];
            for r in 0..7 {
                s.row_into(r, &mut row);
                assert_eq!(&row[..], dense.row(r), "{dtype} row {r}");
            }
        }
    }

    #[test]
    fn finiteness_guard_catches_f16_overflow() {
        let t = Tensor::full(&[2, 2], 1.0e6);
        assert!(!Strip::quantize(&t, StripDType::F16).is_finite());
        assert!(Strip::quantize(&t, StripDType::Bf16).is_finite());
        assert!(Strip::quantize(&t, StripDType::I8).is_finite());
    }
}
