//! Append-only K/V cache slabs for incremental decode.
//!
//! A [`KvCache`] owns two contiguous row-major slabs — keys of width `c`
//! and values of width `cv` — that grow by capacity doubling as a
//! session appends one row per decode step. The slabs are exposed as
//! ordinary [`View2`]s over the *filled* prefix, so the kernel engine's
//! tiled paths ([`crate::kernels::run_decode_step`], prefill) read the
//! cache exactly like any other K/V tensor: no copy, no translation
//! layer. Rows `[0, len)` are immutable once appended — a decode step
//! that snapshotted `len = m` can safely read those rows concurrently
//! with later appends, as long as the owner serializes the append
//! itself (the coordinator does this under the session lock).

use super::View2;

/// Initial row capacity for a fresh cache (grows by doubling).
const INITIAL_ROWS: usize = 64;

/// Append-only K/V slabs with capacity doubling.
#[derive(Debug, Clone)]
pub struct KvCache {
    c: usize,
    cv: usize,
    len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    /// New empty cache for keys of width `c` and values of width `cv`.
    pub fn new(c: usize, cv: usize) -> Self {
        assert!(c > 0 && cv > 0, "KvCache widths must be positive");
        Self {
            c,
            cv,
            len: 0,
            k: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Number of cached positions (rows).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Key width (head dim `c`).
    pub fn c(&self) -> usize {
        self.c
    }

    /// Value width (`cv`).
    pub fn cv(&self) -> usize {
        self.cv
    }

    /// Row capacity currently reserved (before the next doubling).
    pub fn capacity(&self) -> usize {
        if self.c == 0 {
            0
        } else {
            self.k.len() / self.c
        }
    }

    /// Resident slab bytes (both slabs, reserved capacity).
    pub fn resident_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    fn reserve_rows(&mut self, extra: usize) {
        let need = self.len + extra;
        let mut cap = self.capacity();
        if need <= cap {
            return;
        }
        cap = cap.max(INITIAL_ROWS / 2);
        while cap < need {
            cap *= 2;
        }
        self.k.resize(cap * self.c, 0.0);
        self.v.resize(cap * self.cv, 0.0);
    }

    /// Append one position: a key row of width `c` and a value row of
    /// width `cv`. Returns the new row's index.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) -> usize {
        assert_eq!(k_row.len(), self.c, "key row width mismatch");
        assert_eq!(v_row.len(), self.cv, "value row width mismatch");
        self.reserve_rows(1);
        let i = self.len;
        self.k[i * self.c..(i + 1) * self.c].copy_from_slice(k_row);
        self.v[i * self.cv..(i + 1) * self.cv].copy_from_slice(v_row);
        self.len += 1;
        i
    }

    /// Append a block of positions (prefill). `k` must be `(rows, c)`,
    /// `v` must be `(rows, cv)`.
    pub fn append_rows(&mut self, k: View2<'_>, v: View2<'_>) {
        assert_eq!(k.cols, self.c, "key block width mismatch");
        assert_eq!(v.cols, self.cv, "value block width mismatch");
        assert_eq!(k.rows, v.rows, "k/v row count mismatch");
        self.reserve_rows(k.rows);
        let kd = k.data();
        let vd = v.data();
        self.k[self.len * self.c..(self.len + k.rows) * self.c]
            .copy_from_slice(kd);
        self.v[self.len * self.cv..(self.len + v.rows) * self.cv]
            .copy_from_slice(vd);
        self.len += k.rows;
    }

    /// View of the filled key rows, `(len, c)`.
    pub fn k_view(&self) -> View2<'_> {
        View2::new(self.len, self.c, &self.k[..self.len * self.c])
    }

    /// View of the filled value rows, `(len, cv)`.
    pub fn v_view(&self) -> View2<'_> {
        View2::new(self.len, self.cv, &self.v[..self.len * self.cv])
    }

    /// View of the first `rows` key rows — the immutable snapshot a
    /// decode step admitted at cache length `rows` attends, even if the
    /// cache has grown since (append-at-submit never mutates `[0, rows)`).
    pub fn k_prefix(&self, rows: usize) -> View2<'_> {
        assert!(rows <= self.len, "prefix beyond filled rows");
        View2::new(rows, self.c, &self.k[..rows * self.c])
    }

    /// View of the first `rows` value rows (see [`Self::k_prefix`]).
    pub fn v_prefix(&self, rows: usize) -> View2<'_> {
        assert!(rows <= self.len, "prefix beyond filled rows");
        View2::new(rows, self.cv, &self.v[..rows * self.cv])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_view_roundtrip() {
        let mut cache = KvCache::new(3, 2);
        assert!(cache.is_empty());
        for i in 0..5 {
            let k = [i as f32, 1.0, 2.0];
            let v = [10.0 + i as f32, -1.0];
            assert_eq!(cache.append(&k, &v), i);
        }
        assert_eq!(cache.len(), 5);
        let kv = cache.k_view();
        let vv = cache.v_view();
        assert_eq!((kv.rows, kv.cols), (5, 3));
        assert_eq!((vv.rows, vv.cols), (5, 2));
        for i in 0..5 {
            assert_eq!(kv.row(i)[0], i as f32);
            assert_eq!(vv.row(i)[0], 10.0 + i as f32);
        }
    }

    #[test]
    fn capacity_doubles_and_rows_survive_growth() {
        let mut cache = KvCache::new(2, 2);
        let mut caps = Vec::new();
        for i in 0..200 {
            cache.append(&[i as f32, 0.0], &[i as f32, 1.0]);
            caps.push(cache.capacity());
        }
        // Capacity is monotone and each jump is a doubling.
        for w in caps.windows(2) {
            assert!(w[1] == w[0] || w[1] == w[0] * 2);
        }
        assert!(cache.capacity() >= 200);
        for i in 0..200 {
            assert_eq!(cache.k_view().at(i, 0), i as f32);
            assert_eq!(cache.v_view().at(i, 0), i as f32);
        }
    }

    #[test]
    fn append_rows_matches_per_row_appends() {
        let kd: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let vd: Vec<f32> = (0..8).map(|x| -(x as f32)).collect();
        let k = View2::new(4, 3, &kd);
        let v = View2::new(4, 2, &vd);

        let mut a = KvCache::new(3, 2);
        a.append_rows(k, v);
        let mut b = KvCache::new(3, 2);
        for i in 0..4 {
            b.append(k.row(i), v.row(i));
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.k_view().data(), b.k_view().data());
        assert_eq!(a.v_view().data(), b.v_view().data());
    }

    #[test]
    #[should_panic(expected = "key row width mismatch")]
    fn wrong_key_width_panics() {
        let mut cache = KvCache::new(4, 4);
        cache.append(&[0.0; 3], &[0.0; 4]);
    }
}
