//! Build-anywhere stand-in for the vendored `xla` (PJRT) bindings.
//!
//! The PJRT runtime (`crate::runtime`) was written against the
//! `xla_extension` 0.5.1 bindings, which only exist in the vendored
//! accelerator image. To keep the whole crate — planner, simulator,
//! coordinator, benches — building in environments without that crate,
//! `runtime` imports this module under the name `xla`. Every
//! entry point that would touch a real PJRT client returns a descriptive
//! error from [`PjRtClient::cpu`], so `Runtime::open*` fails cleanly and
//! artifact-dependent paths degrade to "run on the accelerator image".
//!
//! Swapping in the real backend is a two-line change in
//! `runtime/mod.rs`: replace `use crate::xla_stub as xla;` with the real
//! crate and add the dependency to `rust/Cargo.toml`.

use anyhow::{bail, Result};

fn unavailable<T>() -> Result<T> {
    bail!(
        "PJRT backend unavailable: this build uses the xla stub (the \
         vendored `xla_extension` bindings are not present); host and \
         simulator executors remain fully functional"
    )
}

/// Element types the artifact manifests declare.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    /// Present so dtype matches keep a reachable catch-all arm.
    Unsupported,
}

/// Host literal (stub: never holds data — construction paths are only
/// reachable after a successful client, which the stub refuses).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Shape of an array literal.
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }

    pub fn ty(&self) -> ElementType {
        ElementType::Unsupported
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Loaded executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client. The stub refuses to construct one, which is the single
/// gate that keeps every other stub path unreachable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self,
                   _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_refuses_cleanly() {
        let err = match PjRtClient::cpu() {
            Ok(_) => panic!("stub must not construct a client"),
            Err(e) => format!("{e}"),
        };
        assert!(err.contains("stub"));
    }

    #[test]
    fn stub_literal_paths_error_not_panic() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.array_shape().is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(Literal.to_tuple().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(PjRtBuffer.to_literal_sync().is_err());
        assert!(PjRtLoadedExecutable
            .execute::<Literal>(&[])
            .is_err());
    }
}
