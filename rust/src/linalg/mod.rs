//! Host-side linear algebra: one-sided Jacobi SVD, truncated SVD factors,
//! singular-value energy spectra and rank-for-energy selection — the
//! machinery behind the paper's Figures 6/8/9 and the SVD decomposition
//! strategy (Table 1b).

use crate::tensor::Tensor;

/// Full SVD result: `a ≈ u · diag(s) · vᵀ` with `u: (n, k)`, `s: (k,)`,
/// `v: (m, k)`, `k = min(n, m)`; singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

/// One-sided Jacobi SVD (Hestenes). Numerically robust for the modest,
/// well-conditioned matrices we decompose (bias tables ≤ ~1k); cost
/// O(n·m²) per sweep, converging in ~5–15 sweeps.
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.rank(), 2);
    let (n, m) = (a.shape()[0], a.shape()[1]);
    if n < m {
        // work on the transpose and swap factors back
        let Svd { u, s, v } = svd(&a.t());
        return Svd { u: v, s, v: u };
    }
    // Work array: columns of `w` get orthogonalized in place.
    // w = a (n × m), v accumulates the right rotations (m × m).
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; m * m];
    for i in 0..m {
        v[i * m + i] = 1.0;
    }
    let col = |w: &Vec<f64>, j: usize| -> Vec<f64> {
        (0..n).map(|i| w[i * m + j]).collect()
    };
    let _ = col; // (kept simple below; direct indexing)

    let eps = 1e-12f64;
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..m {
            for q in (p + 1)..m {
                // dot products over column p and q
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..n {
                    let wp = w[i * m + p];
                    let wq = w[i * m + q];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) off-diagonal
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w[i * m + p];
                    let wq = w[i * m + q];
                    w[i * m + p] = c * wp - s * wq;
                    w[i * m + q] = s * wp + c * wq;
                }
                for i in 0..m {
                    let vp = v[i * m + p];
                    let vq = v[i * m + q];
                    v[i * m + p] = c * vp - s * vq;
                    v[i * m + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-15 {
            break;
        }
    }

    // singular values = column norms of w; u = normalized columns
    let mut order: Vec<usize> = (0..m).collect();
    let mut sigmas = vec![0.0f64; m];
    for (j, sig) in sigmas.iter_mut().enumerate() {
        let mut sum = 0.0f64;
        for i in 0..n {
            sum += w[i * m + j] * w[i * m + j];
        }
        *sig = sum.sqrt();
    }
    order.sort_by(|&a, &b| sigmas[b].partial_cmp(&sigmas[a]).unwrap());

    let mut u_data = vec![0.0f32; n * m];
    let mut v_data = vec![0.0f32; m * m];
    let mut s_out = vec![0.0f32; m];
    for (dst, &src) in order.iter().enumerate() {
        let sig = sigmas[src];
        s_out[dst] = sig as f32;
        let inv = if sig > 1e-30 { 1.0 / sig } else { 0.0 };
        for i in 0..n {
            u_data[i * m + dst] = (w[i * m + src] * inv) as f32;
        }
        for i in 0..m {
            v_data[i * m + dst] = v[i * m + src] as f32;
        }
    }
    Svd {
        u: Tensor::new(&[n, m], u_data),
        s: s_out,
        v: Tensor::new(&[m, m], v_data),
    }
}

/// Truncated SVD factor pair: bias ≈ φ_q φ_kᵀ with
/// `φ_q = U_R √Σ_R (n × R)`, `φ_k = V_R √Σ_R (m × R)` — Table 1b.
pub fn svd_factors(a: &Tensor, rank: usize) -> (Tensor, Tensor) {
    let Svd { u, s, v } = svd(a);
    let (n, m) = (a.shape()[0], a.shape()[1]);
    let k = s.len();
    let r = rank.min(k);
    let mut pq = vec![0.0f32; n * r];
    let mut pk = vec![0.0f32; m * r];
    for j in 0..r {
        let root = s[j].max(0.0).sqrt();
        for i in 0..n {
            pq[i * r + j] = u.at2(i, j) * root;
        }
        for i in 0..m {
            pk[i * r + j] = v.at2(i, j) * root;
        }
    }
    (Tensor::new(&[n, r], pq), Tensor::new(&[m, r], pk))
}

/// Cumulative squared-singular-value energy fractions (Remark 3.8).
pub fn energy_spectrum(a: &Tensor) -> Vec<f64> {
    let s = svd(a).s;
    let energies: Vec<f64> = s.iter().map(|&x| (x as f64) * (x as f64)).collect();
    let total: f64 = energies.iter().sum::<f64>().max(1e-300);
    let mut cum = 0.0;
    energies
        .iter()
        .map(|e| {
            cum += e;
            cum / total
        })
        .collect()
}

/// Smallest R whose truncated SVD keeps ≥ `target` energy (Figure 8).
pub fn rank_for_energy(a: &Tensor, target: f64) -> usize {
    let cum = energy_spectrum(a);
    cum.iter().position(|&c| c >= target).map_or(cum.len(), |p| p + 1)
}

/// Numerical rank: #singular values above `tol * s_max`.
pub fn numerical_rank(a: &Tensor, tol: f32) -> usize {
    let s = svd(a).s;
    let smax = s.first().copied().unwrap_or(0.0);
    s.iter().filter(|&&x| x > tol * smax).count()
}

/// Relative Frobenius reconstruction error of a factor pair.
pub fn reconstruction_error(bias: &Tensor, pq: &Tensor, pk: &Tensor) -> f32 {
    pq.matmul_t(pk).rel_err(bias)
}

/// Best rank-R approximation error predicted by the spectrum
/// (Eckart–Young): sqrt(1 − energy(R)).
pub fn eckart_young_error(a: &Tensor, rank: usize) -> f64 {
    let cum = energy_spectrum(a);
    if rank == 0 {
        return 1.0;
    }
    let e = cum.get(rank - 1).copied().unwrap_or(1.0);
    (1.0 - e).max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn reconstruct(svd: &Svd) -> Tensor {
        let (n, k) = (svd.u.shape()[0], svd.s.len());
        let _m = svd.v.shape()[0];
        let mut us = vec![0.0f32; n * k];
        for i in 0..n {
            for j in 0..k {
                us[i * k + j] = svd.u.at2(i, j) * svd.s[j];
            }
        }
        Tensor::new(&[n, k], us).matmul_t(&svd.v)
    }

    #[test]
    fn svd_reconstructs_random_matrix() {
        let mut rng = Xoshiro256::new(0);
        let a = Tensor::randn(&[20, 12], 1.0, &mut rng);
        let d = svd(&a);
        assert!(reconstruct(&d).rel_err(&a) < 1e-4);
    }

    #[test]
    fn svd_wide_matrix() {
        let mut rng = Xoshiro256::new(1);
        let a = Tensor::randn(&[8, 17], 1.0, &mut rng);
        let d = svd(&a);
        assert_eq!(d.u.shape(), &[8, 8]);
        assert_eq!(d.v.shape(), &[17, 8]);
        assert!(reconstruct(&d).rel_err(&a) < 1e-4);
    }

    #[test]
    fn svd_singular_values_sorted_and_match_norm() {
        let mut rng = Xoshiro256::new(2);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let fro: f32 = d.s.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((fro - a.norm()).abs() / a.norm() < 1e-4);
    }

    #[test]
    fn svd_orthogonal_u() {
        let mut rng = Xoshiro256::new(3);
        let a = Tensor::randn(&[24, 10], 1.0, &mut rng);
        let d = svd(&a);
        let gram = d.u.t().matmul(&d.u);
        assert!(gram.allclose(&Tensor::eye(10), 1e-3, 1e-3));
    }

    #[test]
    fn svd_exact_lowrank_detected() {
        let mut rng = Xoshiro256::new(4);
        let p = Tensor::randn(&[30, 4], 1.0, &mut rng);
        let q = Tensor::randn(&[25, 4], 1.0, &mut rng);
        let a = p.matmul_t(&q);
        assert_eq!(numerical_rank(&a, 1e-4), 4);
        let (pq, pk) = svd_factors(&a, 4);
        assert!(reconstruction_error(&a, &pq, &pk) < 1e-3);
    }

    #[test]
    fn svd_factors_shapes() {
        let mut rng = Xoshiro256::new(5);
        let a = Tensor::randn(&[12, 18], 1.0, &mut rng);
        let (pq, pk) = svd_factors(&a, 5);
        assert_eq!(pq.shape(), &[12, 5]);
        assert_eq!(pk.shape(), &[18, 5]);
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Xoshiro256::new(6);
        let a = Tensor::randn(&[24, 24], 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for r in [1, 2, 4, 8, 16, 24] {
            let (pq, pk) = svd_factors(&a, r);
            let err = reconstruction_error(&a, &pq, &pk);
            assert!(err <= last + 1e-5, "rank {r}: {err} > {last}");
            last = err;
        }
        assert!(last < 1e-3); // full rank ≈ exact
    }

    #[test]
    fn energy_spectrum_monotone_to_one() {
        let mut rng = Xoshiro256::new(7);
        let a = Tensor::randn(&[15, 15], 1.0, &mut rng);
        let cum = energy_spectrum(&a);
        for w in cum.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!((cum.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_for_energy_on_known_spectrum() {
        // diag(3, 2, 1): energies 9/14, 13/14, 14/14
        let a = Tensor::from_fn(&[3, 3], |ix| {
            if ix[0] == ix[1] {
                (3 - ix[0]) as f32
            } else {
                0.0
            }
        });
        assert_eq!(rank_for_energy(&a, 0.60), 1);
        assert_eq!(rank_for_energy(&a, 0.90), 2);
        assert_eq!(rank_for_energy(&a, 0.99), 3);
    }

    #[test]
    fn eckart_young_matches_actual_truncation() {
        let mut rng = Xoshiro256::new(8);
        let a = Tensor::randn(&[20, 20], 1.0, &mut rng);
        for r in [2usize, 5, 10] {
            let (pq, pk) = svd_factors(&a, r);
            let actual = reconstruction_error(&a, &pq, &pk) as f64;
            let predicted = eckart_young_error(&a, r);
            assert!(
                (actual - predicted).abs() < 5e-3,
                "rank {r}: actual {actual} vs predicted {predicted}"
            );
        }
    }

    #[test]
    fn svd_zero_matrix() {
        let a = Tensor::zeros(&[6, 4]);
        let d = svd(&a);
        assert!(d.s.iter().all(|&s| s == 0.0));
    }
}
