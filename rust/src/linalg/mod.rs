//! Host-side linear algebra: one-sided Jacobi SVD, truncated SVD factors,
//! randomized range-finder SVD (Halko et al., *Finding Structure with
//! Randomness*), singular-value energy spectra and rank-for-energy
//! selection — the machinery behind the paper's Figures 6/8/9 and the
//! SVD decomposition strategy (Table 1b).
//!
//! The Jacobi SVD is the exact reference oracle: O(N·M²) per sweep,
//! fine for the modest tables the planner measures. For large tables at
//! small target rank, [`randomized_svd`] sketches the range with a
//! Gaussian projection and runs the Jacobi on an `(R+p) × M` projected
//! matrix instead — O(N·M·(R+p)) — which `decompose` uses for the cold
//! path of big factorizations.

use crate::tensor::Tensor;
use crate::util::Xoshiro256;

/// Full SVD result: `a ≈ u · diag(s) · vᵀ` with `u: (n, k)`, `s: (k,)`,
/// `v: (m, k)`, `k = min(n, m)`; singular values sorted descending.
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

/// One-sided Jacobi SVD (Hestenes). Numerically robust for the modest,
/// well-conditioned matrices we decompose (bias tables ≤ ~1k); cost
/// O(n·m²) per sweep, converging in ~5–15 sweeps.
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.rank(), 2);
    let (n, m) = (a.shape()[0], a.shape()[1]);
    if n < m {
        // work on the transpose and swap factors back
        let Svd { u, s, v } = svd(&a.t());
        return Svd { u: v, s, v: u };
    }
    // Work array: columns of `w` get orthogonalized in place.
    // w = a (n × m), v accumulates the right rotations (m × m).
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; m * m];
    for i in 0..m {
        v[i * m + i] = 1.0;
    }
    let eps = 1e-12f64;
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..m {
            for q in (p + 1)..m {
                // dot products over column p and q
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..n {
                    let wp = w[i * m + p];
                    let wq = w[i * m + q];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) off-diagonal
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w[i * m + p];
                    let wq = w[i * m + q];
                    w[i * m + p] = c * wp - s * wq;
                    w[i * m + q] = s * wp + c * wq;
                }
                for i in 0..m {
                    let vp = v[i * m + p];
                    let vq = v[i * m + q];
                    v[i * m + p] = c * vp - s * vq;
                    v[i * m + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-15 {
            break;
        }
    }

    // singular values = column norms of w; u = normalized columns
    let mut order: Vec<usize> = (0..m).collect();
    let mut sigmas = vec![0.0f64; m];
    for (j, sig) in sigmas.iter_mut().enumerate() {
        let mut sum = 0.0f64;
        for i in 0..n {
            sum += w[i * m + j] * w[i * m + j];
        }
        *sig = sum.sqrt();
    }
    order.sort_by(|&a, &b| sigmas[b].total_cmp(&sigmas[a]));

    let mut u_data = vec![0.0f32; n * m];
    let mut v_data = vec![0.0f32; m * m];
    let mut s_out = vec![0.0f32; m];
    for (dst, &src) in order.iter().enumerate() {
        let sig = sigmas[src];
        s_out[dst] = sig as f32;
        let inv = if sig > 1e-30 { 1.0 / sig } else { 0.0 };
        for i in 0..n {
            u_data[i * m + dst] = (w[i * m + src] * inv) as f32;
        }
        for i in 0..m {
            v_data[i * m + dst] = v[i * m + src] as f32;
        }
    }
    Svd {
        u: Tensor::new(&[n, m], u_data),
        s: s_out,
        v: Tensor::new(&[m, m], v_data),
    }
}

/// Truncated factor pair from an already-computed SVD:
/// `φ_q = U_R √Σ_R (n × R)`, `φ_k = V_R √Σ_R (m × R)` — the one place
/// the Table 1b factor convention lives (the exact and randomized
/// paths, and the planner's fused scan+truncate, all call this).
pub fn factors_from_svd(d: &Svd, rank: usize) -> (Tensor, Tensor) {
    let (n, m) = (d.u.shape()[0], d.v.shape()[0]);
    let r = rank.min(d.s.len());
    let mut pq = vec![0.0f32; n * r];
    let mut pk = vec![0.0f32; m * r];
    for j in 0..r {
        let root = d.s[j].max(0.0).sqrt();
        for i in 0..n {
            pq[i * r + j] = d.u.at2(i, j) * root;
        }
        for i in 0..m {
            pk[i * r + j] = d.v.at2(i, j) * root;
        }
    }
    (Tensor::new(&[n, r], pq), Tensor::new(&[m, r], pk))
}

/// Truncated SVD factor pair: bias ≈ φ_q φ_kᵀ (Table 1b).
pub fn svd_factors(a: &Tensor, rank: usize) -> (Tensor, Tensor) {
    factors_from_svd(&svd(a), rank)
}

/// Orthonormalize the columns of a 2-D tensor in place (modified
/// Gram–Schmidt with f64 accumulation). Columns that become numerically
/// zero are left as exact zeros — projections onto them contribute
/// nothing downstream.
pub fn orthonormalize_columns(t: &mut Tensor) {
    assert_eq!(t.rank(), 2);
    let (n, l) = (t.shape()[0], t.shape()[1]);
    let data = t.data_mut();
    for j in 0..l {
        for p in 0..j {
            let mut dot = 0.0f64;
            for i in 0..n {
                dot += data[i * l + p] as f64 * data[i * l + j] as f64;
            }
            for i in 0..n {
                let proj = dot * data[i * l + p] as f64;
                data[i * l + j] -= proj as f32;
            }
        }
        let mut norm = 0.0f64;
        for i in 0..n {
            let x = data[i * l + j] as f64;
            norm += x * x;
        }
        let norm = norm.sqrt();
        if norm > 1e-12 {
            let inv = (1.0 / norm) as f32;
            for i in 0..n {
                data[i * l + j] *= inv;
            }
        } else {
            for i in 0..n {
                data[i * l + j] = 0.0;
            }
        }
    }
}

/// Randomized range-finder truncated SVD (Halko–Martinsson–Tropp):
/// sketch `Y = A·Ω` with a Gaussian `Ω (M × (rank+oversample))`,
/// orthonormalize, optionally run `power_iters` subspace iterations
/// (sharpens decaying spectra), then take the exact Jacobi SVD of the
/// small projected matrix `B = QᵀA` and lift `U = Q·U_B`.
///
/// Returns `rank + oversample` (clamped to `min(N, M)`) components,
/// sorted descending; truncate to `rank` for the Eckart–Young
/// approximation. Cost is O(N·M·(rank+oversample)) per pass instead of
/// the Jacobi's O(N·M²) — the fast cold path for large bias tables.
/// Falls back to the exact [`svd`] when the sketch would be as wide as
/// the matrix.
pub fn randomized_svd(a: &Tensor, rank: usize, oversample: usize,
                      power_iters: usize,
                      rng: &mut Xoshiro256) -> Svd {
    assert_eq!(a.rank(), 2);
    let (n, m) = (a.shape()[0], a.shape()[1]);
    let k = n.min(m);
    let l = (rank + oversample).max(1).min(k);
    if l >= k {
        return svd(a);
    }
    let omega = Tensor::randn(&[m, l], 1.0, rng);
    let mut q = a.matmul(&omega); // (n, l)
    orthonormalize_columns(&mut q);
    if power_iters > 0 {
        let at = a.t();
        for _ in 0..power_iters {
            let mut z = at.matmul(&q); // (m, l)
            orthonormalize_columns(&mut z);
            q = a.matmul(&z); // (n, l)
            orthonormalize_columns(&mut q);
        }
    }
    let b = q.t().matmul(a); // (l, m), l < m
    let Svd { u: ub, s, v } = svd(&b); // ub (l, l), v (m, l)
    let u = q.matmul(&ub); // (n, l)
    Svd { u, s, v }
}

/// Truncated factor pair from the randomized SVD, in the same
/// `φ_q = U_R √Σ_R`, `φ_k = V_R √Σ_R` convention as [`svd_factors`].
pub fn randomized_svd_factors(a: &Tensor, rank: usize, oversample: usize,
                              power_iters: usize, rng: &mut Xoshiro256)
                              -> (Tensor, Tensor) {
    factors_from_svd(
        &randomized_svd(a, rank, oversample, power_iters, rng),
        rank,
    )
}

/// Cumulative squared-singular-value energy fractions of a spectrum.
pub fn spectrum_energy(s: &[f32]) -> Vec<f64> {
    let energies: Vec<f64> =
        s.iter().map(|&x| (x as f64) * (x as f64)).collect();
    let total: f64 = energies.iter().sum::<f64>().max(1e-300);
    let mut cum = 0.0;
    energies
        .iter()
        .map(|e| {
            cum += e;
            cum / total
        })
        .collect()
}

/// Cumulative squared-singular-value energy fractions (Remark 3.8).
pub fn energy_spectrum(a: &Tensor) -> Vec<f64> {
    spectrum_energy(&svd(a).s)
}

/// Smallest R keeping ≥ `target` energy, from an existing spectrum —
/// lets callers that already hold an [`Svd`] scan and truncate with
/// one decomposition instead of two.
pub fn rank_for_energy_in(s: &[f32], target: f64) -> usize {
    let cum = spectrum_energy(s);
    cum.iter().position(|&c| c >= target).map_or(cum.len(), |p| p + 1)
}

/// Smallest R whose truncated SVD keeps ≥ `target` energy (Figure 8).
pub fn rank_for_energy(a: &Tensor, target: f64) -> usize {
    rank_for_energy_in(&svd(a).s, target)
}

/// Numerical rank: #singular values above `tol * s_max`.
pub fn numerical_rank(a: &Tensor, tol: f32) -> usize {
    let s = svd(a).s;
    let smax = s.first().copied().unwrap_or(0.0);
    s.iter().filter(|&&x| x > tol * smax).count()
}

/// Relative Frobenius reconstruction error of a factor pair.
pub fn reconstruction_error(bias: &Tensor, pq: &Tensor, pk: &Tensor) -> f32 {
    pq.matmul_t(pk).rel_err(bias)
}

/// `‖A Bᵀ‖_F` for factor strips `A: (n, r)`, `B: (m, r)` — computed as
/// `√trace((AᵀA)(BᵀB))` via the two r×r Gram matrices, O((n+m)·r² + r³)
/// with f64 accumulation, never materializing the n×m product. This is
/// the cheap exact norm the quantization error bound
/// ([`crate::decompose::quantize_factors`]) and the planner's dtype
/// policy are built on.
pub fn factored_frob_norm(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape()[1], b.shape()[1], "factor rank mismatch");
    let r = a.shape()[1];
    let gram = |t: &Tensor| -> Vec<f64> {
        let rows = t.shape()[0];
        let mut g = vec![0.0f64; r * r];
        for i in 0..rows {
            let row = t.row(i);
            for p in 0..r {
                let xp = row[p] as f64;
                for q in p..r {
                    g[p * r + q] += xp * row[q] as f64;
                }
            }
        }
        // mirror the upper triangle
        for p in 0..r {
            for q in 0..p {
                g[p * r + q] = g[q * r + p];
            }
        }
        g
    };
    let (ga, gb) = (gram(a), gram(b));
    // trace(Ga·Gb) = Σ_pq Ga[p,q]·Gb[q,p]; both are symmetric
    let mut tr = 0.0f64;
    for p in 0..r {
        for q in 0..r {
            tr += ga[p * r + q] * gb[p * r + q];
        }
    }
    tr.max(0.0).sqrt()
}

/// Best rank-R approximation error predicted by the spectrum
/// (Eckart–Young): sqrt(1 − energy(R)).
pub fn eckart_young_error(a: &Tensor, rank: usize) -> f64 {
    let cum = energy_spectrum(a);
    if rank == 0 {
        return 1.0;
    }
    let e = cum.get(rank - 1).copied().unwrap_or(1.0);
    (1.0 - e).max(0.0).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn reconstruct(svd: &Svd) -> Tensor {
        let (n, k) = (svd.u.shape()[0], svd.s.len());
        let _m = svd.v.shape()[0];
        let mut us = vec![0.0f32; n * k];
        for i in 0..n {
            for j in 0..k {
                us[i * k + j] = svd.u.at2(i, j) * svd.s[j];
            }
        }
        Tensor::new(&[n, k], us).matmul_t(&svd.v)
    }

    #[test]
    fn svd_reconstructs_random_matrix() {
        let mut rng = Xoshiro256::new(0);
        let a = Tensor::randn(&[20, 12], 1.0, &mut rng);
        let d = svd(&a);
        assert!(reconstruct(&d).rel_err(&a) < 1e-4);
    }

    #[test]
    fn svd_wide_matrix() {
        let mut rng = Xoshiro256::new(1);
        let a = Tensor::randn(&[8, 17], 1.0, &mut rng);
        let d = svd(&a);
        assert_eq!(d.u.shape(), &[8, 8]);
        assert_eq!(d.v.shape(), &[17, 8]);
        assert!(reconstruct(&d).rel_err(&a) < 1e-4);
    }

    #[test]
    fn svd_singular_values_sorted_and_match_norm() {
        let mut rng = Xoshiro256::new(2);
        let a = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5);
        }
        let fro: f32 = d.s.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((fro - a.norm()).abs() / a.norm() < 1e-4);
    }

    #[test]
    fn svd_orthogonal_u() {
        let mut rng = Xoshiro256::new(3);
        let a = Tensor::randn(&[24, 10], 1.0, &mut rng);
        let d = svd(&a);
        let gram = d.u.t().matmul(&d.u);
        assert!(gram.allclose(&Tensor::eye(10), 1e-3, 1e-3));
    }

    #[test]
    fn svd_exact_lowrank_detected() {
        let mut rng = Xoshiro256::new(4);
        let p = Tensor::randn(&[30, 4], 1.0, &mut rng);
        let q = Tensor::randn(&[25, 4], 1.0, &mut rng);
        let a = p.matmul_t(&q);
        assert_eq!(numerical_rank(&a, 1e-4), 4);
        let (pq, pk) = svd_factors(&a, 4);
        assert!(reconstruction_error(&a, &pq, &pk) < 1e-3);
    }

    #[test]
    fn svd_factors_shapes() {
        let mut rng = Xoshiro256::new(5);
        let a = Tensor::randn(&[12, 18], 1.0, &mut rng);
        let (pq, pk) = svd_factors(&a, 5);
        assert_eq!(pq.shape(), &[12, 5]);
        assert_eq!(pk.shape(), &[18, 5]);
    }

    #[test]
    fn truncation_error_decreases_with_rank() {
        let mut rng = Xoshiro256::new(6);
        let a = Tensor::randn(&[24, 24], 1.0, &mut rng);
        let mut last = f32::INFINITY;
        for r in [1, 2, 4, 8, 16, 24] {
            let (pq, pk) = svd_factors(&a, r);
            let err = reconstruction_error(&a, &pq, &pk);
            assert!(err <= last + 1e-5, "rank {r}: {err} > {last}");
            last = err;
        }
        assert!(last < 1e-3); // full rank ≈ exact
    }

    #[test]
    fn energy_spectrum_monotone_to_one() {
        let mut rng = Xoshiro256::new(7);
        let a = Tensor::randn(&[15, 15], 1.0, &mut rng);
        let cum = energy_spectrum(&a);
        for w in cum.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
        assert!((cum.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank_for_energy_on_known_spectrum() {
        // diag(3, 2, 1): energies 9/14, 13/14, 14/14
        let a = Tensor::from_fn(&[3, 3], |ix| {
            if ix[0] == ix[1] {
                (3 - ix[0]) as f32
            } else {
                0.0
            }
        });
        assert_eq!(rank_for_energy(&a, 0.60), 1);
        assert_eq!(rank_for_energy(&a, 0.90), 2);
        assert_eq!(rank_for_energy(&a, 0.99), 3);
    }

    #[test]
    fn eckart_young_matches_actual_truncation() {
        let mut rng = Xoshiro256::new(8);
        let a = Tensor::randn(&[20, 20], 1.0, &mut rng);
        for r in [2usize, 5, 10] {
            let (pq, pk) = svd_factors(&a, r);
            let actual = reconstruction_error(&a, &pq, &pk) as f64;
            let predicted = eckart_young_error(&a, r);
            assert!(
                (actual - predicted).abs() < 5e-3,
                "rank {r}: actual {actual} vs predicted {predicted}"
            );
        }
    }

    #[test]
    fn svd_zero_matrix() {
        let a = Tensor::zeros(&[6, 4]);
        let d = svd(&a);
        assert!(d.s.iter().all(|&s| s == 0.0));
    }

    #[test]
    fn orthonormalize_columns_gives_orthonormal_basis() {
        let mut rng = Xoshiro256::new(10);
        let mut t = Tensor::randn(&[30, 6], 1.0, &mut rng);
        orthonormalize_columns(&mut t);
        let gram = t.t().matmul(&t);
        assert!(gram.allclose(&Tensor::eye(6), 1e-4, 1e-4));
    }

    #[test]
    fn orthonormalize_zeroes_dependent_columns() {
        // two identical columns: the second must collapse to zero
        let t0 = Tensor::from_fn(&[8, 2], |ix| (ix[0] + 1) as f32);
        let mut t = t0.clone();
        orthonormalize_columns(&mut t);
        for i in 0..8 {
            assert_eq!(t.at2(i, 1), 0.0, "row {i}");
        }
    }

    #[test]
    fn randomized_svd_recovers_exact_lowrank() {
        let mut rng = Xoshiro256::new(11);
        let p = Tensor::randn(&[64, 4], 1.0, &mut rng);
        let q = Tensor::randn(&[48, 4], 1.0, &mut rng);
        let a = p.matmul_t(&q);
        let (pq, pk) = randomized_svd_factors(&a, 4, 8, 2, &mut rng);
        assert_eq!(pq.shape(), &[64, 4]);
        assert_eq!(pk.shape(), &[48, 4]);
        assert!(reconstruction_error(&a, &pq, &pk) < 1e-3);
    }

    #[test]
    fn randomized_svd_matches_jacobi_on_decaying_spectrum() {
        let mut rng = Xoshiro256::new(12);
        // smooth + small noise: the Swin-like spectral profile
        let base = Tensor::randn(&[60, 6], 1.0, &mut rng);
        let a = base
            .matmul_t(&base)
            .add(&Tensor::randn(&[60, 60], 0.01, &mut rng));
        for r in [2usize, 4, 6] {
            let (pq, pk) = randomized_svd_factors(&a, r, 8, 2, &mut rng);
            let rand_err = reconstruction_error(&a, &pq, &pk) as f64;
            let (jq, jk) = svd_factors(&a, r);
            let jacobi_err = reconstruction_error(&a, &jq, &jk) as f64;
            // the sketch can't beat Eckart–Young; it must come close
            assert!(rand_err + 1e-4 >= jacobi_err, "rank {r}");
            assert!(
                rand_err <= jacobi_err + 0.05,
                "rank {r}: randomized {rand_err} vs jacobi {jacobi_err}"
            );
        }
    }

    #[test]
    fn factored_frob_norm_matches_materialized_product() {
        let mut rng = Xoshiro256::new(21);
        let a = Tensor::randn(&[23, 5], 1.3, &mut rng);
        let b = Tensor::randn(&[17, 5], 0.7, &mut rng);
        let dense = a.matmul_t(&b).norm() as f64;
        let gram = factored_frob_norm(&a, &b);
        assert!((gram - dense).abs() <= dense * 1e-4,
                "gram {gram} vs dense {dense}");
        // degenerate shapes stay exact and finite
        assert_eq!(factored_frob_norm(&Tensor::zeros(&[4, 2]),
                                      &Tensor::zeros(&[3, 2])), 0.0);
    }

    #[test]
    fn randomized_svd_wide_sketch_falls_back_exact() {
        let mut rng = Xoshiro256::new(13);
        let a = Tensor::randn(&[10, 8], 1.0, &mut rng);
        // rank + oversample ≥ min dim → exact Jacobi result
        let d = randomized_svd(&a, 6, 8, 0, &mut rng);
        let exact = svd(&a);
        for (x, y) in d.s.iter().zip(&exact.s) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}
