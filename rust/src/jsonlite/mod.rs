//! Minimal JSON codec — enough to parse `artifacts/manifest.json` and emit
//! metrics/config dumps. No serde in the vendored universe, so this is a
//! small hand-rolled recursive-descent parser + printer.
//!
//! Supported: objects, arrays, strings (with \uXXXX escapes), numbers
//! (f64), booleans, null. Numbers are kept as f64 — fine for manifest
//! shapes (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Recursion limit for nested arrays/objects: deep enough for any real
/// manifest or store file, shallow enough that adversarial input (e.g.
/// `[[[[…`) errors out long before the thread stack is at risk.
pub const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Infinity; `format!` would emit an
                    // unparseable token and corrupt the document.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{}", x));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    /// Track container nesting; errors past [`MAX_DEPTH`] so hostile
    /// input cannot blow the parse stack.
    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            Err(self.err("nesting deeper than 128 levels"))
        } else {
            Ok(())
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.enter()?;
        let v = self.object_inner();
        self.depth -= 1;
        v
    }

    fn object_inner(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.enter()?;
        let v = self.array_inner();
        self.depth -= 1;
        v
    }

    fn array_inner(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    let chunk = std::str::from_utf8(
                        &self.bytes[start..self.pos.min(self.bytes.len())],
                    )
                    .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": "x"}], "c": null, "d": false}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(),
                   Some("x"));
        assert!(j.get("c").is_null());
        assert_eq!(j.get("d").as_bool(), Some(false));
        assert!(j.get("missing").is_null());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn parse_raw_utf8() {
        assert_eq!(Json::parse("\"φ_q\"").unwrap(), Json::Str("φ_q".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"flag":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_dump_without_fraction() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
