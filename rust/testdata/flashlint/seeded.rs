// Seeded flashlint violation corpus.
//
// This file is NOT compiled into the crate: it lives outside `src/` and
// is loaded with `include_str!` by `tests/flashlint_rules.rs`, which
// lints it under the synthetic path `src/factorstore/seeded.rs` so every
// path-scoped rule (R1, R3, R4) applies. Each item below exercises one
// rule; the test asserts per-rule diagnostic counts, so keep the set of
// violations in sync with `EXPECTED` over there if you edit this file.

use std::sync::Mutex; // raw-sync: raw std::sync import

// lock-unwrap: one panicked holder poisons the lock for everyone.
pub fn poison_prone(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

// raw-sync: a lock constructed without an audit name literal.
pub fn unnamed_lock() -> Mutex<u32> {
    Mutex::new(0)
}

// io-under-lock: file write inside the guard's live range.
pub fn io_under_guard(file_lock: &SpillLock, buf: &[u8]) {
    let mut g = file_lock.lock_recover();
    g.file.write_all(buf).ok();
}

// nonfinite-persist: serializing factors with no finiteness check in
// the enclosing function.
pub fn persist_unchecked(key: u64, value: &Cached) -> Json {
    entry_to_json(key, value)
}

// hot-path-panic: `serve_loop` is a root in the hot-path manifest, so
// both the .expect() here and the panic! in the helper it calls are
// reachable panic sites. It also pulls `emit_metrics` onto the serving
// path for the unordered-iteration seed below.
pub fn serve_loop(stats: &HashMap<String, u64>) {
    let spec = lookup_spec().expect("spec must exist");
    helper(spec);
    let _ = emit_metrics(stats);
}

fn helper(x: u32) {
    if x == 0 {
        panic!("boom");
    }
}

// alloc-in-hotpath: `bias_row_into` is an [inner] root in the hot-path
// manifest (and not on its [scratch] allowlist), so both the vec! and
// the .to_vec() are per-row heap allocations — two findings.
pub fn bias_row_into(row: &[f32], out: &mut [f32]) {
    let tmp = vec![0.0f32; out.len()];
    let copy = row.to_vec();
    out.copy_from_slice(&copy[..out.len().min(tmp.len())]);
}

// unordered-iteration, serving scope: `serve_loop` (a [serving] root)
// calls this, and `stats` is hash-keyed — emission order varies run to
// run.
fn emit_metrics(stats: &HashMap<String, u64>) -> u64 {
    let mut total = 0;
    for (_k, v) in stats.iter() {
        total += *v;
    }
    total
}

// unordered-iteration, sink scope: not on the serving path at all, but
// the iteration's output flows into `save` (an order sink), so the
// persisted bytes depend on hasher seed.
fn save(path: &str, blob: &str) {
    let _ = (path, blob);
}

fn dump_registry(reg: &HashMap<u64, u32>) {
    let mut s = String::new();
    for (k, v) in reg.iter() {
        s.push_str(&format_pair(*k, *v));
    }
    save("registry", &s);
}

// uncapped-read: the write_frame/read_frame mentions put this file in
// wire scope. `relay` reads a peer-controlled length with .read_exact
// (one finding); `serve_once` accepts a socket and does frame io
// without ever calling set_io_timeouts (second finding).
fn relay(sock: &mut TcpStream, buf: &mut [u8]) {
    sock.read_exact(buf).ok();
    let _ = write_frame(sock, buf);
}

fn serve_once(l: &TcpListener) {
    if let Ok((mut s, _)) = l.accept() {
        let _ = read_frame(&mut s);
    }
}

// dispatch-blocking: `net_dispatch_loop` is the [roots] entry of
// dispatch.txt. The blocking recv, the blocking enqueue, and a non-try
// lock whose receiver is not in [leaf-locks] are three findings.
pub fn net_dispatch_loop(rx: &Receiver<Work>, pool: &WorkerPool) {
    let work = rx.recv();
    let _ = pool.dispatch_blocking(work);
    let _g = registry.lock();
}

// stale-allow: the allocation this annotation once excused is gone;
// an allow that suppresses nothing is itself a finding.
pub fn tidy_scratch(out: &mut [f32]) {
    // flashlint: allow(alloc-in-hotpath) scratch reuse landed; nothing allocates here anymore
    out.fill(0.0);
}

// Suppression proof: the same lock-unwrap pattern as `poison_prone`,
// silenced by a line-form allow with a reason. The test asserts this
// contributes to `suppressed`, not to the diagnostics.
pub fn suppressed_ok(m: &Mutex<u32>) -> u32 {
    // flashlint: allow(lock-unwrap) seeded corpus: proves line-form suppression works
    *m.lock().unwrap()
}

// flashlint: allow(no-such-rule) malformed on purpose: unknown rule name
