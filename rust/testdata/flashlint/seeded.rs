// Seeded flashlint violation corpus.
//
// This file is NOT compiled into the crate: it lives outside `src/` and
// is loaded with `include_str!` by `tests/flashlint_rules.rs`, which
// lints it under the synthetic path `src/factorstore/seeded.rs` so every
// path-scoped rule (R1, R3, R4) applies. Each item below exercises one
// rule; the test asserts per-rule diagnostic counts, so keep the set of
// violations in sync with `EXPECTED` over there if you edit this file.

use std::sync::Mutex; // raw-sync: raw std::sync import

// lock-unwrap: one panicked holder poisons the lock for everyone.
pub fn poison_prone(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}

// raw-sync: a lock constructed without an audit name literal.
pub fn unnamed_lock() -> Mutex<u32> {
    Mutex::new(0)
}

// io-under-lock: file write inside the guard's live range.
pub fn io_under_guard(file_lock: &SpillLock, buf: &[u8]) {
    let mut g = file_lock.lock_recover();
    g.file.write_all(buf).ok();
}

// nonfinite-persist: serializing factors with no finiteness check in
// the enclosing function.
pub fn persist_unchecked(key: u64, value: &Cached) -> Json {
    entry_to_json(key, value)
}

// hot-path-panic: `serve_loop` is a root in the hot-path manifest, so
// both the .expect() here and the panic! in the helper it calls are
// reachable panic sites.
pub fn serve_loop() {
    let spec = lookup_spec().expect("spec must exist");
    helper(spec);
}

fn helper(x: u32) {
    if x == 0 {
        panic!("boom");
    }
}

// Suppression proof: the same lock-unwrap pattern as `poison_prone`,
// silenced by a line-form allow with a reason. The test asserts this
// contributes to `suppressed`, not to the diagnostics.
pub fn suppressed_ok(m: &Mutex<u32>) -> u32 {
    // flashlint: allow(lock-unwrap) seeded corpus: proves line-form suppression works
    *m.lock().unwrap()
}

// flashlint: allow(no-such-rule) malformed on purpose: unknown rule name
