//! Self-test for flashlint: a seeded violation corpus proves every rule
//! fires, the suppression forms work, and — the real acceptance gate —
//! the repo's own sources lint clean.
//!
//! The corpus lives at `testdata/flashlint/seeded.rs` (outside `src/`,
//! so cargo never compiles it) and is linted under a synthetic
//! `src/factorstore/` path so the path-scoped rules apply.

use flashbias::lint::{collect_rs_files, lint_sources, render_json, LintConfig, Report};

const SEEDED: &str = include_str!("../testdata/flashlint/seeded.rs");

fn lint_one(path: &str, src: &str) -> Report {
    lint_sources(&[(path.to_string(), src.to_string())], &LintConfig::default())
}

fn count(report: &Report, rule: &str) -> usize {
    report.diagnostics.iter().filter(|d| d.rule == rule).count()
}

fn seeded_report() -> Report {
    lint_one("src/factorstore/seeded.rs", SEEDED)
}

#[test]
fn every_rule_fires_on_the_seeded_corpus() {
    let r = seeded_report();
    // One entry per (rule, expected count); keep in sync with the
    // corpus comments in testdata/flashlint/seeded.rs.
    let expected: &[(&str, usize)] = &[
        ("lock-unwrap", 1),        // poison_prone
        ("raw-sync", 2),           // std::sync import + unnamed Mutex::new
        ("io-under-lock", 1),      // write_all under the guard
        ("nonfinite-persist", 1),  // entry_to_json without a guard
        ("hot-path-panic", 2),     // .expect in serve_loop, panic! in helper
        ("alloc-in-hotpath", 2),   // vec! + .to_vec() in bias_row_into
        ("unordered-iteration", 2),// emit_metrics (serving), dump_registry (sink)
        ("uncapped-read", 2),      // relay read_exact, serve_once w/o timeouts
        ("dispatch-blocking", 3),  // recv, dispatch_blocking, non-try lock
        ("stale-allow", 1),        // tidy_scratch's obsolete allow
        ("bad-allow", 1),          // unknown rule name in an annotation
    ];
    for &(rule, n) in expected {
        assert_eq!(
            count(&r, rule),
            n,
            "rule {rule}: expected {n} diagnostic(s), got {:#?}",
            r.diagnostics
        );
    }
    let total: usize = expected.iter().map(|&(_, n)| n).sum();
    assert_eq!(r.diagnostics.len(), total, "{:#?}", r.diagnostics);
    assert!(!r.clean());
}

#[test]
fn line_allow_suppresses_and_is_counted() {
    // The corpus carries exactly one legitimate suppression: the
    // allow(lock-unwrap) line in `suppressed_ok`.
    let r = seeded_report();
    assert_eq!(r.suppressed, 1);
    // ...and the suppressed site must not also appear as a diagnostic:
    // only `poison_prone` contributes a lock-unwrap.
    assert_eq!(count(&r, "lock-unwrap"), 1);
}

#[test]
fn hot_path_provenance_names_the_root() {
    let r = seeded_report();
    let panics: Vec<&str> = r
        .diagnostics
        .iter()
        .filter(|d| d.rule == "hot-path-panic")
        .map(|d| d.message.as_str())
        .collect();
    assert!(panics.iter().any(|m| m.contains("root `serve_loop`")),
            "{panics:?}");
    assert!(panics.iter().any(|m| m.contains("serve_loop -> helper")),
            "{panics:?}");
}

/// The call graph must resolve a method call through the receiver's
/// *type*, not its name: two impls defining `emit` are different nodes,
/// and only the one the receiver is typed to contributes reachability.
#[test]
fn callgraph_distinguishes_same_named_methods_on_different_impls() {
    let src_for = |ty: &str| {
        format!(
            "\
pub struct Quiet;
pub struct Loud;

impl Quiet {{
    pub fn emit(&self) -> u32 {{
        1
    }}
}}

impl Loud {{
    pub fn emit(&self) -> u32 {{
        panic!(\"boom\")
    }}
}}

pub fn serve_loop() {{
    let worker = {ty} {{}};
    let _ = worker.emit();
}}
"
        )
    };
    // Receiver typed to the panic-free impl: Loud::emit is a distinct,
    // unreachable node, so the hot path is clean.
    let quiet = lint_one("src/server/seeded_impls.rs", &src_for("Quiet"));
    assert_eq!(count(&quiet, "hot-path-panic"), 0, "{:#?}", quiet.diagnostics);
    // Same source, receiver typed to the panicking impl: one finding,
    // with the call chain in the provenance.
    let loud = lint_one("src/server/seeded_impls.rs", &src_for("Loud"));
    assert_eq!(count(&loud, "hot-path-panic"), 1, "{:#?}", loud.diagnostics);
    assert!(
        loud.diagnostics[0].message.contains("serve_loop -> emit"),
        "{:?}",
        loud.diagnostics[0].message
    );
}

/// Non-try locks on dispatch-thread paths are findings *except* for the
/// receivers vouched for in dispatch.txt [leaf-locks]; try_ variants are
/// always fine.
#[test]
fn leaf_locks_and_try_variants_pass_dispatch_rule() {
    let src = "\
pub fn net_dispatch_loop(h: &SessionHandle) {
    let _g = state.lock();
    let _p = plans.try_read();
}
";
    let r = lint_one("src/server/x.rs", src);
    assert_eq!(count(&r, "dispatch-blocking"), 0, "{:#?}", r.diagnostics);
}

#[test]
fn fn_allow_suppresses_whole_function() {
    let src = "\
pub fn risky(m: &M) -> u32 {
    // flashlint: allow-fn(lock-unwrap) test: fn-form covers later lines too
    let a = *m.lock().unwrap();
    let b = *m.lock().unwrap();
    a + b
}
pub fn still_flagged(m: &M) -> u32 {
    *m.lock().unwrap()
}
";
    let r = lint_one("src/coordinator/x.rs", src);
    assert_eq!(r.suppressed, 2, "{:#?}", r.diagnostics);
    assert_eq!(count(&r, "lock-unwrap"), 1);
    assert_eq!(r.diagnostics[0].line, 8);
}

#[test]
fn file_allow_suppresses_whole_file() {
    let src = "\
// flashlint: allow-file(lock-unwrap) test: file-form covers everything
pub fn a(m: &M) -> u32 { *m.lock().unwrap() }
pub fn b(m: &M) -> u32 { *m.lock().unwrap() }
";
    let r = lint_one("src/server/x.rs", src);
    assert!(r.clean(), "{:#?}", r.diagnostics);
    assert_eq!(r.suppressed, 2);
}

#[test]
fn reasonless_allow_is_bad_and_does_not_suppress() {
    let src = "\
pub fn a(m: &M) -> u32 {
    // flashlint: allow(lock-unwrap)
    *m.lock().unwrap()
}
";
    let r = lint_one("src/runtime/x.rs", src);
    assert_eq!(count(&r, "bad-allow"), 1, "{:#?}", r.diagnostics);
    assert_eq!(count(&r, "lock-unwrap"), 1, "reasonless allow must not suppress");
    assert_eq!(r.suppressed, 0);
}

#[test]
fn allow_for_wrong_rule_does_not_suppress() {
    let src = "\
pub fn a(m: &M) -> u32 {
    // flashlint: allow(io-under-lock) wrong rule on purpose
    *m.lock().unwrap()
}
";
    let r = lint_one("src/factorstore/x.rs", src);
    assert_eq!(count(&r, "lock-unwrap"), 1, "{:#?}", r.diagnostics);
    assert_eq!(r.suppressed, 0);
}

#[test]
fn test_code_is_exempt() {
    let src = "\
#[cfg(test)]
mod tests {
    #[test]
    fn t(m: &M) { m.lock().unwrap(); }
}
";
    let r = lint_one("src/coordinator/x.rs", src);
    assert!(r.clean(), "{:#?}", r.diagnostics);
}

#[test]
fn json_report_roundtrips_through_jsonlite() {
    let r = seeded_report();
    let j = flashbias::jsonlite::Json::parse(&render_json(&r)).expect("valid json");
    assert_eq!(j.get("violations").as_usize(), Some(r.diagnostics.len()));
    assert_eq!(j.get("suppressed").as_usize(), Some(1));
    let diags = j.get("diagnostics").as_arr().expect("array");
    assert_eq!(diags.len(), r.diagnostics.len());
    assert!(diags.iter().all(|d| d.get("rule").as_str().is_some()
        && d.get("line").as_usize().is_some()
        && d.get("hint").as_str().is_some()));
}

/// The acceptance gate: the crate's own sources must lint clean. This is
/// the same scan `make lint` / the CI analysis job runs, executed here
/// so `cargo test` alone catches a regression.
#[test]
fn repo_sources_lint_clean() {
    // Integration tests run with CWD = the package root (rust/).
    let paths = collect_rs_files(std::path::Path::new("src")).expect("walk src/");
    assert!(paths.len() >= 20, "suspiciously few sources: {paths:?}");
    let files: Vec<(String, String)> = paths
        .iter()
        .map(|p| {
            (
                p.to_string_lossy().replace('\\', "/"),
                std::fs::read_to_string(p).expect("read source"),
            )
        })
        .collect();
    let r = lint_sources(&files, &LintConfig::default());
    assert!(
        r.clean(),
        "flashlint found unsuppressed violations in the tree:\n{}",
        flashbias::lint::render_text(&r)
    );
}
