//! The unified plan API: the Table 1 decision procedure must pick the
//! paper's row for every bias in the zoo, and Factored plans must
//! reproduce dense-bias attention exactly (Eq. 3), causal and
//! non-causal, over random geometry — no artifacts required.

use flashbias::attention::{self, AttnOpts};
use flashbias::bias::swin_relative_bias;
use flashbias::decompose::NeuralConfig;
use flashbias::iomodel::Geometry;
use flashbias::plan::{
    self, BiasSpec, Decision, ExecMode, PlanError, PlanOptions, Planner,
    SelectorConfig,
};
use flashbias::proplite::{forall, gen_dim, Config};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

const SRAM: usize = 100 * 1024 / 2;

fn geo(n: usize, m: usize, c: usize) -> Geometry {
    Geometry { n, m, c, r: 0, sram: SRAM }
}

// ---------------------------------------------------------------------------
// Table 1: the decision procedure picks the paper's row per bias class
// ---------------------------------------------------------------------------

#[test]
fn table1_alibi_picks_exact() {
    let plan = Planner::default()
        .plan(&BiasSpec::alibi(64, 64, 0.25), &geo(64, 64, 64),
              &PlanOptions::default())
        .unwrap();
    assert!(matches!(plan.decision, Decision::Exact { rank: 2 }));
    assert!(matches!(plan.mode, ExecMode::Factored { .. }));
}

#[test]
fn table1_spatial_distance_picks_exact_rank_3d() {
    let mut rng = Xoshiro256::new(0);
    let x = Tensor::randn(&[48, 3], 1.0, &mut rng);
    let plan = Planner::default()
        .plan(&BiasSpec::spatial(x.clone(), x, None), &geo(48, 48, 64),
              &PlanOptions::default())
        .unwrap();
    assert!(matches!(plan.decision, Decision::Exact { rank: 9 }));
    assert_eq!(plan.rank(), 9);
}

#[test]
fn table1_cos_multiplicative_picks_exact() {
    let plan = Planner::default()
        .plan(&BiasSpec::cos_multiplicative(32, 32), &geo(32, 32, 64),
              &PlanOptions::default())
        .unwrap();
    assert!(matches!(plan.decision, Decision::Exact { rank: 2 }));
    assert!(plan.multiplicative);
}

#[test]
fn table1_static_learned_picks_svd_under_energy_target() {
    // a learned table that is genuinely low-rank under the energy
    // target: rank-8 structure plus a small full-rank tail
    let mut rng = Xoshiro256::new(5);
    let a = Tensor::randn(&[64, 8], 1.0, &mut rng);
    let b = Tensor::randn(&[64, 8], 1.0, &mut rng);
    let table = a.matmul_t(&b)
        .add(&Tensor::randn(&[64, 64], 1e-3, &mut rng));
    let plan = Planner::default()
        .plan(&BiasSpec::static_learned(table), &geo(64, 64, 64),
              &PlanOptions::default())
        .unwrap();
    match &plan.decision {
        Decision::Svd { rank, rel_err } => {
            // limit = ceil(64 · 0.35) = 23; the measured rank ≈ 8
            assert!(*rank <= 23, "rank {rank} above the fraction limit");
            // 99% energy → ≤ ~10% Frobenius error (Eckart–Young)
            assert!(*rel_err <= 0.11, "rel_err {rel_err}");
        }
        other => panic!("static low-rank table must plan SVD: {other:?}"),
    }
    // a real Swin-style table goes through the same procedure and lands
    // on SVD or dense-fallback purely by its measured spectrum
    let swin = swin_relative_bias((12, 12), 1, 0, 6, 0.02).remove(0);
    let plan = Planner::default()
        .plan(&BiasSpec::static_learned(swin), &geo(144, 144, 64),
              &PlanOptions::default())
        .unwrap();
    assert!(matches!(
        plan.decision,
        Decision::Svd { .. } | Decision::DenseFallback { .. }
    ));
}

#[test]
fn table1_dynamic_picks_neural() {
    let n = 32;
    let x = Tensor::from_fn(&[n, 2], |ix| {
        let t = ix[0] as f32 / n as f32;
        if ix[1] == 0 { (6.28 * t).sin() } else { t }
    });
    let target = x.matmul_t(&x).map(|v| v.tanh());
    let planner = Planner::new(SelectorConfig {
        neural: NeuralConfig {
            rank: 8,
            hidden: 24,
            steps: 300,
            lr: 5e-3,
            ..NeuralConfig::default()
        },
        ..SelectorConfig::default()
    });
    let plan = planner
        .plan(&BiasSpec::dynamic(x.clone(), x, target), &geo(n, n, 16),
              &PlanOptions::default())
        .unwrap();
    assert!(matches!(plan.decision, Decision::Neural { rank: 8, .. }));
    assert!(matches!(plan.mode, ExecMode::Factored { .. }));
}

#[test]
fn table1_full_rank_opaque_falls_back_dense() {
    // iid Gaussian matrix: spectrum is flat, the rank test must fail
    let mut rng = Xoshiro256::new(1);
    let table = Tensor::randn(&[64, 64], 1.0, &mut rng);
    let plan = Planner::default()
        .plan(&BiasSpec::dense(table), &geo(64, 64, 64),
              &PlanOptions::default())
        .unwrap();
    assert!(
        matches!(plan.decision, Decision::DenseFallback { .. }),
        "full-rank table must fall back: {:?}",
        plan.decision
    );
    assert!(matches!(plan.mode, ExecMode::Dense { .. }));
    assert_eq!(plan.rank(), 0);
    assert_eq!(plan.predicted_io, plan.dense_io);
}

#[test]
fn table1_no_bias_plans_pure_flash() {
    let plan = Planner::default()
        .plan(&BiasSpec::None, &geo(128, 128, 64),
              &PlanOptions::default())
        .unwrap();
    assert!(matches!(plan.decision, Decision::NoBias));
    assert_eq!(plan.bias_storage_bytes, 0);
}

#[test]
fn rank_override_bypasses_fraction_test() {
    // Pangu case: R = 56 of 144 exceeds the 0.35 fraction but the paper
    // pins it — the override must keep SVD
    let table = swin_relative_bias((12, 12), 1, 3, 6, 0.02).remove(0);
    let plan = Planner::default()
        .plan(
            &BiasSpec::static_learned(table),
            &geo(144, 144, 32),
            &PlanOptions {
                rank_override: Some(56),
                ..PlanOptions::default()
            },
        )
        .unwrap();
    assert!(matches!(plan.decision, Decision::Svd { rank: 56, .. }));
}

#[test]
fn planner_errors_are_typed() {
    let planner = Planner::default();
    assert!(matches!(
        planner.plan(&BiasSpec::alibi(16, 16, 0.5), &geo(16, 32, 8),
                     &PlanOptions::default()),
        Err(PlanError::ShapeMismatch { .. })
    ));
    assert!(matches!(
        planner.plan(
            &BiasSpec::cos_multiplicative(16, 16),
            &geo(16, 16, 8),
            &PlanOptions { causal: true, ..PlanOptions::default() }
        ),
        Err(PlanError::CausalMultiplicative)
    ));
}

// ---------------------------------------------------------------------------
// Property: Factored plans reproduce dense-bias attention (Eq. 3)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct Case {
    n: usize,
    m: usize,
    c: usize,
    slope: f32,
    causal: bool,
    seed: u64,
}

fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    for (n, m) in [(c.n / 2, c.m), (c.n, c.m / 2), (c.n / 2, c.m / 2)] {
        if n >= 2 && m >= 2 {
            out.push(Case { n, m, ..c.clone() });
        }
    }
    if c.c > 2 {
        out.push(Case { c: c.c / 2, ..c.clone() });
    }
    out
}

/// plan → execute vs the dense-bias reference, on one case.
fn factored_matches_dense(case: &Case) -> bool {
    let mut rng = Xoshiro256::new(case.seed);
    let q = Tensor::randn(&[case.n, case.c], 1.0, &mut rng);
    let k = Tensor::randn(&[case.m, case.c], 1.0, &mut rng);
    let v = Tensor::randn(&[case.m, case.c], 1.0, &mut rng);
    let spec = BiasSpec::alibi(case.n, case.m, case.slope);
    let plan = match Planner::default().plan(
        &spec,
        &geo(case.n, case.m, case.c),
        &PlanOptions { causal: case.causal, ..PlanOptions::default() },
    ) {
        Ok(p) => p,
        Err(_) => return false,
    };
    if !matches!(plan.mode, ExecMode::Factored { .. }) {
        return false;
    }
    let got = match plan::execute(&plan, &q, &k, &v) {
        Ok(t) => t,
        Err(_) => return false,
    };
    let dense = attention::attention(
        &q,
        &k,
        &v,
        Some(&spec.materialize().unwrap()),
        &AttnOpts { causal: case.causal },
    );
    got.rel_err(&dense) <= 1e-5
}

#[test]
fn prop_factored_plan_reproduces_dense_attention() {
    forall(
        Config::default().cases(60),
        |rng| Case {
            n: gen_dim(rng, 2, 40),
            m: gen_dim(rng, 2, 40),
            c: gen_dim(rng, 2, 16),
            slope: (rng.uniform(0.05, 1.0)) as f32,
            causal: false,
            seed: rng.next_u64(),
        },
        shrink_case,
        factored_matches_dense,
    );
}

#[test]
fn prop_factored_plan_reproduces_dense_attention_causal() {
    forall(
        Config::default().cases(60).seed(0xCA05A1),
        |rng| Case {
            n: gen_dim(rng, 2, 40),
            m: gen_dim(rng, 2, 40),
            c: gen_dim(rng, 2, 16),
            slope: (rng.uniform(0.05, 1.0)) as f32,
            causal: true,
            seed: rng.next_u64(),
        },
        shrink_case,
        factored_matches_dense,
    );
}

#[test]
fn prop_svd_plan_of_exactly_low_rank_table_is_exact() {
    // a table that IS low-rank (a·bᵀ): the planner's SVD at the measured
    // rank must reproduce dense attention within f32 tolerance
    forall(
        Config::default().cases(20).seed(7),
        |rng| (gen_dim(rng, 8, 32), gen_dim(rng, 2, 4), rng.next_u64()),
        |t| {
            let mut out = Vec::new();
            if t.0 > 8 {
                out.push((t.0 / 2, t.1, t.2));
            }
            out
        },
        |&(n, r, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let a = Tensor::randn(&[n, r], 0.5, &mut rng);
            let b = Tensor::randn(&[n, r], 0.5, &mut rng);
            let table = a.matmul_t(&b);
            let q = Tensor::randn(&[n, 8], 1.0, &mut rng);
            let k = Tensor::randn(&[n, 8], 1.0, &mut rng);
            let v = Tensor::randn(&[n, 8], 1.0, &mut rng);
            let plan = Planner::default()
                .plan(
                    &BiasSpec::static_learned(table.clone()),
                    &geo(n, n, 8),
                    &PlanOptions {
                        rank_override: Some(r),
                        ..PlanOptions::default()
                    },
                )
                .expect("plan low-rank table");
            let got = plan::execute(&plan, &q, &k, &v).expect("execute");
            let dense = attention::attention(&q, &k, &v, Some(&table),
                                             &AttnOpts::default());
            got.rel_err(&dense) <= 1e-4
        },
    );
}

// ---------------------------------------------------------------------------
// Executor coherence: host and simulator agree on every plan
// ---------------------------------------------------------------------------

#[test]
fn host_and_simulator_agree_across_the_zoo() {
    use flashbias::plan::{Executor, HostExecutor, SimExecutor};
    let mut rng = Xoshiro256::new(3);
    let n = 24;
    let q = Tensor::randn(&[n, 8], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 8], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 8], 1.0, &mut rng);
    let x = Tensor::randn(&[n, 2], 1.0, &mut rng);
    let table = Tensor::randn(&[n, n], 1.0, &mut rng);
    let specs = [
        BiasSpec::None,
        BiasSpec::alibi(n, n, 0.25),
        BiasSpec::spatial(x.clone(), x, None),
        BiasSpec::dense(table),
    ];
    let planner = Planner::default();
    let sim = SimExecutor::default();
    for spec in &specs {
        for causal in [false, true] {
            let plan = planner
                .plan(
                    spec,
                    &geo(n, n, 8),
                    &PlanOptions { causal, ..PlanOptions::default() },
                )
                .unwrap_or_else(|e| panic!("{}: {e}", spec.kind()));
            let h = HostExecutor.execute(&plan, &q, &k, &v).unwrap();
            let s = sim.execute(&plan, &q, &k, &v).unwrap();
            assert!(
                s.allclose(&h, 1e-4, 1e-4),
                "{} causal={causal}: sim != host",
                spec.kind()
            );
            assert!(sim.last_report().unwrap().hbm_total() > 0);
        }
    }
}
