//! Cross-layer numerics: the rust host-side reference attention
//! (`flashbias::attention`) must agree with the AOT-compiled Pallas
//! kernels executed through PJRT, on the *same* inputs (read back from
//! the artifact input dumps). This pins L3's host math against L1's
//! kernels through the full interchange pipeline.

use flashbias::attention::{self, AttnOpts};
use flashbias::runtime::HostValue;
use flashbias::tensor::Tensor;

mod common;
use common::runtime;

fn f32_input(inputs: &[HostValue], i: usize) -> &Tensor {
    inputs[i].as_f32().expect("f32 input")
}

#[test]
fn host_attention_matches_pallas_pure() {
    let Some(rt) = runtime() else { return };
    let name = "attn_pure_n256";
    let inputs = rt.example_inputs(name).unwrap();
    let got = rt.load(name).unwrap().run(&inputs).unwrap();
    let out = got[0].as_f32().unwrap();
    let (q, k, v) = (
        f32_input(&inputs, 0),
        f32_input(&inputs, 1),
        f32_input(&inputs, 2),
    );
    let host = attention::mha(q, k, v, None, &AttnOpts::default());
    let rel = out.rel_err(&host);
    assert!(rel < 1e-4, "pure: rel {rel}");
}

#[test]
fn host_attention_matches_pallas_dense_bias() {
    let Some(rt) = runtime() else { return };
    let name = "attn_dense_n256";
    let inputs = rt.example_inputs(name).unwrap();
    let got = rt.load(name).unwrap().run(&inputs).unwrap();
    let out = got[0].as_f32().unwrap();
    let host = attention::mha(
        f32_input(&inputs, 0),
        f32_input(&inputs, 1),
        f32_input(&inputs, 2),
        Some(f32_input(&inputs, 3)),
        &AttnOpts::default(),
    );
    let rel = out.rel_err(&host);
    assert!(rel < 1e-4, "dense: rel {rel}");
}

#[test]
fn host_attention_matches_pallas_factored() {
    let Some(rt) = runtime() else { return };
    let name = "attn_factored_n256";
    let inputs = rt.example_inputs(name).unwrap();
    let got = rt.load(name).unwrap().run(&inputs).unwrap();
    let out = got[0].as_f32().unwrap();
    let (q, k, v) = (
        f32_input(&inputs, 0),
        f32_input(&inputs, 1),
        f32_input(&inputs, 2),
    );
    let (pq, pk) = (f32_input(&inputs, 3), f32_input(&inputs, 4));
    // per head: host factored attention (Eq. 3 concat)
    let h = q.shape()[0];
    let heads: Vec<Tensor> = (0..h)
        .map(|i| {
            attention::attention_factored(
                &q.index0(i),
                &k.index0(i),
                &v.index0(i),
                &pq.index0(i),
                &pk.index0(i),
                &AttnOpts::default(),
            )
        })
        .collect();
    let host = Tensor::stack(&heads);
    let rel = out.rel_err(&host);
    assert!(rel < 1e-4, "factored: rel {rel}");
}

#[test]
fn host_attention_matches_pallas_causal() {
    let Some(rt) = runtime() else { return };
    let name = "causal_pure_n256";
    let inputs = rt.example_inputs(name).unwrap();
    let got = rt.load(name).unwrap().run(&inputs).unwrap();
    let out = got[0].as_f32().unwrap();
    let host = attention::mha(
        f32_input(&inputs, 0),
        f32_input(&inputs, 1),
        f32_input(&inputs, 2),
        None,
        &AttnOpts { causal: true },
    );
    let rel = out.rel_err(&host);
    assert!(rel < 1e-4, "causal: rel {rel}");
}

#[test]
fn host_multiplicative_matches_kernel() {
    let Some(rt) = runtime() else { return };
    let name = "mult_factored_n256";
    let inputs = rt.example_inputs(name).unwrap();
    let got = rt.load(name).unwrap().run(&inputs).unwrap();
    let out = got[0].as_f32().unwrap();
    let (q, k, v) = (
        f32_input(&inputs, 0).index0(0),
        f32_input(&inputs, 1).index0(0),
        f32_input(&inputs, 2).index0(0),
    );
    let bias = f32_input(&inputs, 3)
        .index0(0)
        .matmul_t(&f32_input(&inputs, 4).index0(0));
    let host = attention::attention_multiplicative(&q, &k, &v, &bias);
    let rel = out.index0(0).rel_err(&host);
    assert!(rel < 1e-4, "mult: rel {rel}");
}

#[test]
fn exact_alibi_factors_match_python_layout() {
    // The rust Alibi factorization must reproduce the python-side factor
    // strips baked into causal_alibi_factored (same slopes, same layout).
    use flashbias::bias::{Alibi, ExactBias};
    let Some(rt) = runtime() else { return };
    let inputs = rt.example_inputs("causal_alibi_factored_n256").unwrap();
    let pq = f32_input(&inputs, 3);
    let pk = f32_input(&inputs, 4);
    let h = pq.shape()[0];
    let n = pq.shape()[1];
    let slopes = Alibi::head_slopes(h);
    for head in 0..h {
        let alibi = Alibi::new(n, n, slopes[head]);
        let dense_from_python =
            pq.index0(head).matmul_t(&pk.index0(head));
        let dense_rust = alibi.dense();
        let rel = dense_from_python.rel_err(&dense_rust);
        assert!(rel < 1e-4, "head {head}: rel {rel}");
    }
}

#[test]
fn rust_svd_reconstructs_swin_factor_quality() {
    // SVD here and SVD in python both hit the Eckart–Young bound, so the
    // reconstruction error of our factors at the same rank must match the
    // artifact's (within noise).
    use flashbias::linalg;
    let biases = flashbias::bias::swin_relative_bias((12, 12), 4, 0, 6, 0.02);
    for b in &biases {
        let (pq, pk) = linalg::svd_factors(b, 16);
        let err = linalg::reconstruction_error(b, &pq, &pk) as f64;
        let bound = linalg::eckart_young_error(b, 16);
        assert!((err - bound).abs() < 0.02,
                "err {err} vs Eckart–Young {bound}");
    }
}
