//! FactorStore integration: fingerprint stability, decompose-exactly-once
//! under concurrency, byte-budget LRU, persistence round-trips, and the
//! acceptance criterion of ISSUE 4 — a repeated `Planner` plan for the
//! same `StaticLearned`/`Dynamic` content through the store performs
//! zero SVD/neural work (hit counter increments, factors are shared).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use flashbias::bias::{pangu_relative_bias, swin_relative_bias};
use flashbias::decompose::NeuralConfig;
use flashbias::factorstore::{Cached, FactorStore, Fingerprint};
use flashbias::iomodel::Geometry;
use flashbias::plan::{
    BiasSpec, Decision, ExecMode, PlanOptions, Planner, SelectorConfig,
    StripPolicy,
};
use flashbias::tensor::{StripDType, Tensor};
use flashbias::util::Xoshiro256;

const SRAM: usize = 100 * 1024 / 2;

fn geo(n: usize, m: usize) -> Geometry {
    Geometry { n, m, c: 32, r: 0, sram: SRAM }
}

/// An exactly low-rank learned table (rank-`r` product plus a tiny
/// full-rank tail) — the planner reliably lands on `Decision::Svd`.
fn lowrank_table(n: usize, r: usize, seed: u64) -> Tensor {
    let mut rng = Xoshiro256::new(seed);
    let a = Tensor::randn(&[n, r], 1.0, &mut rng);
    let b = Tensor::randn(&[n, r], 1.0, &mut rng);
    a.matmul_t(&b).add(&Tensor::randn(&[n, n], 1e-4, &mut rng))
}

// ---------------------------------------------------------------------------
// Fingerprints
// ---------------------------------------------------------------------------

#[test]
fn fingerprint_stable_across_clones_and_sensitive_to_content() {
    let table = swin_relative_bias((8, 8), 1, 0, 6, 0.02).remove(0);
    let a = BiasSpec::static_learned(table.clone());
    let b = BiasSpec::static_learned(table.clone());
    assert_eq!(a.fingerprint(), b.fingerprint());

    // one-element perturbation → new key
    let mut perturbed = table.clone();
    perturbed.set2(2, 7, perturbed.at2(2, 7) + 1e-6);
    assert_ne!(
        a.fingerprint(),
        BiasSpec::static_learned(perturbed).fingerprint()
    );

    // same table under a different kind → new key
    assert_ne!(a.fingerprint(), BiasSpec::dense(table).fingerprint());
}

#[test]
fn fingerprint_covers_dynamic_sources() {
    let mut rng = Xoshiro256::new(3);
    let xq = Tensor::randn(&[10, 2], 1.0, &mut rng);
    let xk = Tensor::randn(&[12, 2], 1.0, &mut rng);
    let bias = Tensor::randn(&[10, 12], 1.0, &mut rng);
    let a = BiasSpec::dynamic(xq.clone(), xk.clone(), bias.clone());
    let b = BiasSpec::dynamic(xq.clone(), xk.clone(), bias.clone());
    assert_eq!(a.fingerprint(), b.fingerprint());
    let mut xq2 = xq.clone();
    xq2.set2(0, 0, xq2.at2(0, 0) + 1e-6);
    assert_ne!(
        a.fingerprint(),
        BiasSpec::dynamic(xq2, xk, bias).fingerprint()
    );
}

// ---------------------------------------------------------------------------
// Concurrency: decompose exactly once
// ---------------------------------------------------------------------------

#[test]
fn concurrent_get_or_decompose_runs_exactly_once() {
    let store = Arc::new(FactorStore::unbounded());
    let calls = Arc::new(AtomicUsize::new(0));
    let key = Fingerprint(0xDECAF);
    let threads = 8;
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let store = store.clone();
            let calls = calls.clone();
            std::thread::spawn(move || {
                store.get_or_insert_with(key, || {
                    calls.fetch_add(1, Ordering::SeqCst);
                    // hold the in-flight cell long enough that the
                    // other threads genuinely contend on it
                    std::thread::sleep(
                        std::time::Duration::from_millis(30),
                    );
                    let mut rng = Xoshiro256::new(1);
                    let pq = Tensor::randn(&[16, 2], 1.0, &mut rng);
                    let pk = Tensor::randn(&[16, 2], 1.0, &mut rng);
                    Cached::Factors(Arc::new(
                        flashbias::decompose::Factors::from_tensors(
                            pq, pk, 0.0, 2,
                        ),
                    ))
                })
            })
        })
        .collect();
    let results: Vec<Cached> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(calls.load(Ordering::SeqCst), 1,
               "decomposition must run exactly once");
    assert_eq!(store.misses(), 1);
    assert_eq!(store.hits(), threads as u64 - 1);
    // everyone shares the same Arc
    let first = results[0].factors().unwrap();
    for r in &results[1..] {
        assert!(Arc::ptr_eq(first, r.factors().unwrap()));
    }
}

// ---------------------------------------------------------------------------
// LRU eviction
// ---------------------------------------------------------------------------

#[test]
fn lru_eviction_respects_byte_budget() {
    // rank-1 strips on an (n, n) bias cost (n + n)·1·4 bytes
    let entry = |n: usize| {
        let mut rng = Xoshiro256::new(n as u64);
        Cached::Factors(Arc::new(
            flashbias::decompose::Factors::from_tensors(
                Tensor::randn(&[n, 1], 1.0, &mut rng),
                Tensor::randn(&[n, 1], 1.0, &mut rng),
                0.0,
                1,
            ),
        ))
    };
    // each entry: 32·4 = 128 bytes; budget holds two
    let store = FactorStore::new(300);
    store.get_or_insert_with(Fingerprint(1), || entry(16));
    store.get_or_insert_with(Fingerprint(2), || entry(16));
    assert_eq!(store.len(), 2);
    assert_eq!(store.total_bytes(), 256);
    // touch 1 → 2 becomes the LRU victim of the next insert
    assert!(store.get(Fingerprint(1)).is_some());
    store.get_or_insert_with(Fingerprint(3), || entry(16));
    assert!(store.total_bytes() <= 300);
    assert_eq!(store.evictions(), 1);
    assert!(store.get(Fingerprint(2)).is_none(), "LRU evicted");
    assert!(store.get(Fingerprint(1)).is_some());
    assert!(store.get(Fingerprint(3)).is_some());
    // an evicted key decomposes again on next demand
    store.get_or_insert_with(Fingerprint(2), || entry(16));
    assert!(store.get(Fingerprint(2)).is_some());
}

#[test]
fn oversized_entry_never_thrashes_the_decomposition() {
    // an entry larger than the whole budget used to evict itself right
    // after insertion, so every later plan silently re-ran the SVD
    let n = 40;
    let spec = BiasSpec::static_learned(lowrank_table(n, 4, 21));
    // rank-4 strips on (40, 40): (40 + 40) * 4 * 4 = 1280 bytes
    let store = FactorStore::new(256);
    let planner = Planner::default();
    let opts = PlanOptions {
        rank_override: Some(4),
        ..PlanOptions::default()
    };
    for _ in 0..3 {
        let plan = planner
            .plan_with_store(&spec, &geo(n, n), &opts, &store)
            .unwrap();
        assert!(matches!(plan.mode, ExecMode::Factored { .. }));
    }
    assert_eq!(store.misses(), 1,
               "the oversized entry must stay resident, not re-SVD");
    assert_eq!(store.hits(), 2);
    assert_eq!(store.evictions(), 0);
}

// ---------------------------------------------------------------------------
// Spill tier: eviction pressure degrades to a disk read, never an SVD
// ---------------------------------------------------------------------------

#[test]
fn budgeted_store_under_pressure_spills_instead_of_redecomposing() {
    let spill = std::env::temp_dir().join(format!(
        "fb_it_spill_{}.jsonl",
        std::process::id()
    ));
    let n = 40;
    let spec_a = BiasSpec::static_learned(lowrank_table(n, 4, 31));
    let spec_b = BiasSpec::static_learned(lowrank_table(n, 4, 32));
    // budget holds exactly one rank-4 pair (1280 bytes): planning the
    // two specs alternately keeps evicting the other into the spill
    let store = FactorStore::new(1280 + 64)
        .spill_to(&spill)
        .expect("spill file");
    let planner = Planner::default();
    let opts = PlanOptions {
        rank_override: Some(4),
        ..PlanOptions::default()
    };
    let first = planner
        .plan_with_store(&spec_a, &geo(n, n), &opts, &store)
        .unwrap();
    planner
        .plan_with_store(&spec_b, &geo(n, n), &opts, &store)
        .unwrap();
    assert_eq!(store.misses(), 2);
    for round in 0..3 {
        let pa = planner
            .plan_with_store(&spec_a, &geo(n, n), &opts, &store)
            .unwrap();
        planner
            .plan_with_store(&spec_b, &geo(n, n), &opts, &store)
            .unwrap();
        assert_eq!(
            store.misses(),
            2,
            "round {round}: eviction pressure must never re-run an SVD"
        );
        // the reloaded strips are bit-identical to the original SVD
        match (&first.mode, &pa.mode) {
            (
                ExecMode::Factored { factors: f0 },
                ExecMode::Factored { factors: f1 },
            ) => {
                assert_eq!(f0.phi_q, f1.phi_q);
                assert_eq!(f0.phi_k, f1.phi_k);
            }
            other => panic!("expected factored plans, got {other:?}"),
        }
    }
    assert_eq!(store.spill_hits(), 6, "two spill reloads per round");
    assert!(store.evictions() >= 6);
    let _ = std::fs::remove_file(spill);
}

// ---------------------------------------------------------------------------
// Persistence: save → load → plan round-trips identical factors
// ---------------------------------------------------------------------------

#[test]
fn save_load_plan_roundtrips_identical_factors() {
    let n = 48;
    let spec = BiasSpec::static_learned(lowrank_table(n, 5, 42));
    let planner = Planner::default();
    let opts = PlanOptions::default();

    let store = FactorStore::unbounded();
    let plan_cold = planner
        .plan_with_store(&spec, &geo(n, n), &opts, &store)
        .expect("cold plan");
    let cold = match &plan_cold.mode {
        ExecMode::Factored { factors } => factors.clone(),
        other => panic!("expected SVD plan, got {other:?}"),
    };

    let path = std::env::temp_dir().join(format!(
        "fb_roundtrip_{}.json",
        std::process::id()
    ));
    store.save(&path).expect("save");
    let loaded =
        FactorStore::load(&path, usize::MAX).expect("load store");
    let _ = std::fs::remove_file(&path);

    let plan_warm = planner
        .plan_with_store(&spec, &geo(n, n), &opts, &loaded)
        .expect("warm plan");
    assert_eq!(loaded.hits(), 1, "loaded store must hit");
    assert_eq!(loaded.misses(), 0);
    match &plan_warm.mode {
        ExecMode::Factored { factors } => {
            assert_eq!(factors.rank, cold.rank);
            assert_eq!(factors.phi_q, cold.phi_q,
                       "φ_q must round-trip exactly");
            assert_eq!(factors.phi_k, cold.phi_k,
                       "φ_k must round-trip exactly");
            assert_eq!(factors.rel_err, cold.rel_err);
        }
        other => panic!("expected SVD plan, got {other:?}"),
    }
    match (&plan_cold.decision, &plan_warm.decision) {
        (
            Decision::Svd { rank: r1, rel_err: e1 },
            Decision::Svd { rank: r2, rel_err: e2 },
        ) => {
            assert_eq!(r1, r2);
            assert_eq!(e1, e2);
        }
        other => panic!("expected matching SVD decisions: {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Acceptance: warm plans do zero decomposition work and share factors
// ---------------------------------------------------------------------------

#[test]
fn warm_static_plan_is_pointer_equal_to_stored_factors() {
    let n = 40;
    let spec = BiasSpec::static_learned(lowrank_table(n, 4, 7));
    let store = FactorStore::unbounded();
    let planner = Planner::default();
    let opts = PlanOptions::default();
    let p1 = planner
        .plan_with_store(&spec, &geo(n, n), &opts, &store)
        .unwrap();
    assert_eq!((store.misses(), store.hits()), (1, 0));
    let p2 = planner
        .plan_with_store(&spec, &geo(n, n), &opts, &store)
        .unwrap();
    assert_eq!((store.misses(), store.hits()), (1, 1),
               "second plan must be a pure hit");
    let (f1, f2) = match (&p1.mode, &p2.mode) {
        (
            ExecMode::Factored { factors: f1 },
            ExecMode::Factored { factors: f2 },
        ) => (f1, f2),
        other => panic!("expected factored plans, got {other:?}"),
    };
    assert!(Arc::ptr_eq(f1, f2),
            "warm plan must share the stored factor allocation");
}

#[test]
fn warm_dynamic_plan_skips_the_neural_fit() {
    let n = 24;
    let x = Tensor::from_fn(&[n, 2], |ix| {
        let t = ix[0] as f32 / n as f32;
        if ix[1] == 0 { (6.28 * t).sin() } else { t }
    });
    let target = x.matmul_t(&x).map(|v| v.tanh());
    let spec = BiasSpec::dynamic(x.clone(), x, target);
    let planner = Planner::new(SelectorConfig {
        neural: NeuralConfig {
            rank: 4,
            hidden: 12,
            steps: 60,
            lr: 5e-3,
            ..NeuralConfig::default()
        },
        ..SelectorConfig::default()
    });
    let store = FactorStore::unbounded();
    let geometry = Geometry { n, m: n, c: 16, r: 0, sram: SRAM };
    let opts = PlanOptions::default();
    let p1 = planner
        .plan_with_store(&spec, &geometry, &opts, &store)
        .unwrap();
    let p2 = planner
        .plan_with_store(&spec, &geometry, &opts, &store)
        .unwrap();
    assert_eq!((store.misses(), store.hits()), (1, 1));
    assert!(matches!(p2.decision, Decision::Neural { rank: 4, .. }));
    match (&p1.mode, &p2.mode) {
        (
            ExecMode::Factored { factors: f1 },
            ExecMode::Factored { factors: f2 },
        ) => assert!(Arc::ptr_eq(f1, f2)),
        other => panic!("expected factored plans, got {other:?}"),
    }
}

#[test]
fn store_plans_execute_identically_to_storeless_plans() {
    // the store must be an invisible optimization: same plan, same math
    let n = 32;
    let spec = BiasSpec::static_learned(lowrank_table(n, 3, 9));
    let planner = Planner::default();
    let opts = PlanOptions::default();
    let store = FactorStore::unbounded();
    let direct = planner.plan(&spec, &geo(n, n), &opts).unwrap();
    // plan twice so the executed plan is the warm (shared-factor) one
    planner
        .plan_with_store(&spec, &geo(n, n), &opts, &store)
        .unwrap();
    let warm = planner
        .plan_with_store(&spec, &geo(n, n), &opts, &store)
        .unwrap();
    let mut rng = Xoshiro256::new(11);
    let q = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let out_direct =
        flashbias::plan::execute(&direct, &q, &k, &v).unwrap();
    let out_warm = flashbias::plan::execute(&warm, &q, &k, &v).unwrap();
    assert!(out_warm.allclose(&out_direct, 0.0, 0.0),
            "store-backed execution must be bit-identical");
}

// ---------------------------------------------------------------------------
// Coordinator: one store shared across the serving loop
// ---------------------------------------------------------------------------

#[test]
fn coordinator_plan_and_register_shares_the_store() {
    use flashbias::coordinator::{Coordinator, CoordinatorConfig};
    use flashbias::runtime::Runtime;

    let store = Arc::new(FactorStore::unbounded());
    let coord = Coordinator::with_store(
        Arc::new(Runtime::empty()),
        CoordinatorConfig::default(),
        store.clone(),
    );
    let n = 36;
    let spec = BiasSpec::static_learned(lowrank_table(n, 4, 13));
    let planner = Planner::default();
    let opts = PlanOptions::default();
    coord
        .plan_and_register("swin_a", &planner, &spec, &geo(n, n), &opts)
        .expect("register a");
    coord
        .plan_and_register("swin_b", &planner, &spec, &geo(n, n), &opts)
        .expect("register b");
    assert_eq!(store.misses(), 1,
               "two registrations of one bias decompose once");
    assert_eq!(store.hits(), 1);
    let (pa, pb) = (
        coord.host_plans().get("swin_a").unwrap(),
        coord.host_plans().get("swin_b").unwrap(),
    );
    match (&pa.mode, &pb.mode) {
        (
            ExecMode::Factored { factors: f1 },
            ExecMode::Factored { factors: f2 },
        ) => assert!(Arc::ptr_eq(f1, f2),
                     "registered plans share factor storage"),
        other => panic!("expected factored plans, got {other:?}"),
    }
    // the coordinator's metrics expose the store counters
    assert!(coord.metrics().summary().contains("store: hits=1"));
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Reduced-precision strips (ISSUE 7 acceptance)
// ---------------------------------------------------------------------------

/// ISSUE 7 acceptance: bf16 strips cut the warm-store resident bytes
/// ≥ 1.9× on a swin + pangu zoo (every entry halves its strip payload,
/// so the exact ratio is 2.0×).
#[test]
fn bf16_strips_shrink_the_warm_zoo_at_least_1_9x() {
    // swin (8,8) windows → N = 64; pangu (2,4,4) windows → N = 32
    let mut zoo: Vec<(BiasSpec, Geometry)> = Vec::new();
    for t in swin_relative_bias((8, 8), 2, 11, 6, 0.02) {
        zoo.push((BiasSpec::static_learned(t), geo(64, 64)));
    }
    for t in pangu_relative_bias((2, 4, 4), 2, 12, 5, 0.02) {
        zoo.push((BiasSpec::static_learned(t), geo(32, 32)));
    }
    // Swin tables at the default energy cut can carry rel_err above the
    // Auto gate (see plan_api.rs), so pin the dtype: Force(Bf16) with a
    // fixed rank makes every entry deterministically quantized.
    let opts = PlanOptions {
        rank_override: Some(8),
        ..PlanOptions::default()
    };
    let warm = |policy: StripPolicy| -> (FactorStore, StripDType) {
        let store = FactorStore::unbounded();
        let planner = Planner::new(SelectorConfig {
            strip_policy: policy,
            ..SelectorConfig::default()
        });
        let mut dtype = StripDType::F32;
        for (spec, g) in &zoo {
            let plan = planner
                .plan_with_store(spec, g, &opts, &store)
                .expect("plan");
            assert!(matches!(plan.mode, ExecMode::Factored { .. }),
                    "zoo entries must be factored for the bytes to count");
            dtype = plan.strip_dtype();
        }
        assert_eq!(store.misses(), zoo.len() as u64,
                   "every zoo entry decomposed exactly once");
        (store, dtype)
    };

    let (f32_store, f32_dtype) = warm(StripPolicy::F32Only);
    let (bf_store, bf_dtype) =
        warm(StripPolicy::Force(StripDType::Bf16));
    assert_eq!(f32_dtype, StripDType::F32);
    assert_eq!(bf_dtype, StripDType::Bf16);

    let (full, half) = (f32_store.total_bytes(), bf_store.total_bytes());
    assert!(half > 0);
    // ≥ 1.9× in integer math: 10·full ≥ 19·half
    assert!(10 * full >= 19 * half,
            "bf16 zoo must be ≥1.9x smaller: f32={full}B bf16={half}B");
    assert_eq!(full, 2 * half,
               "bf16 halves every strip payload exactly");
}
