//! Property tests (proplite) over coordinator + decomposition invariants:
//! routing monotonicity, batch conservation, Eq. (3) equivalence over
//! random shapes, SVD error vs the Eckart–Young bound, exact-bias
//! factorization over random geometry.

use flashbias::attention::{self, AttnOpts};
use flashbias::bias::{Alibi, ExactBias, SpatialDistance};
use flashbias::coordinator::batcher::{BatcherConfig, DynamicBatcher};
use flashbias::coordinator::router::{RouteKey, Router};
use flashbias::coordinator::{Request, RequestKind};
use flashbias::linalg;
use flashbias::proplite::{forall, gen_dim, shrink_usize, Config};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

#[test]
fn prop_router_smallest_adequate_bucket() {
    let mut router = Router::default();
    let key = RouteKey::new("f", "v");
    let buckets = [64usize, 128, 256, 512, 1024];
    for &b in &buckets {
        router.insert(key.clone(), b, &format!("a{b}"));
    }
    forall(
        Config::default().cases(300),
        |rng| gen_dim(rng, 1, 1500),
        |n| shrink_usize(n),
        |&n| match router.route(&key, n) {
            Some((_, bucket)) => {
                bucket >= n
                    && buckets
                        .iter()
                        .filter(|&&b| b >= n)
                        .all(|&b| bucket <= b)
            }
            None => n > 1024,
        },
    );
}

#[test]
fn prop_batcher_conserves_requests() {
    // any submission sequence: flushed + pending == submitted, no dups
    forall(
        Config::default().cases(50),
        |rng| {
            let n = gen_dim(rng, 1, 40);
            (0..n)
                .map(|_| gen_dim(rng, 0, 2)) // artifact index
                .collect::<Vec<_>>()
        },
        |v| flashbias::proplite::shrink_vec(v, |_| vec![]),
        |seq| {
            let mut b = DynamicBatcher::new(BatcherConfig {
                max_batch: 4,
                max_wait: std::time::Duration::from_secs(100),
            });
            let mut flushed_ids = Vec::new();
            for (id, &art) in seq.iter().enumerate() {
                let req = Request {
                    id: id as u64,
                    artifact: format!("a{art}"),
                    inputs: vec![],
                    enqueued: std::time::Instant::now(),
                    kind: RequestKind::Prefill,
                };
                if let Some(batch) = b.push(req) {
                    flushed_ids
                        .extend(batch.requests.iter().map(|r| r.id));
                }
            }
            let pending = b.pending_len();
            for batch in b.flush_all() {
                flushed_ids.extend(batch.requests.iter().map(|r| r.id));
            }
            let _ = pending;
            let mut sorted = flushed_ids.clone();
            sorted.sort_unstable();
            sorted.dedup();
            sorted.len() == seq.len() && flushed_ids.len() == seq.len()
        },
    );
}

#[test]
fn prop_eq3_concat_equals_additive_bias() {
    // Eq. (3) equivalence over random (n, m, c, r)
    forall(
        Config::default().cases(30),
        |rng| {
            (
                gen_dim(rng, 2, 24),
                gen_dim(rng, 2, 24),
                gen_dim(rng, 2, 16),
                gen_dim(rng, 1, 6),
                rng.next_u64(),
            )
        },
        |_| vec![],
        |&(n, m, c, r, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let q = Tensor::randn(&[n, c], 1.0, &mut rng);
            let k = Tensor::randn(&[m, c], 1.0, &mut rng);
            let v = Tensor::randn(&[m, c], 1.0, &mut rng);
            let pq = Tensor::randn(&[n, r], 0.3, &mut rng);
            let pk = Tensor::randn(&[m, r], 0.3, &mut rng);
            let bias = pq.matmul_t(&pk);
            let dense = attention::attention(&q, &k, &v, Some(&bias),
                                             &AttnOpts::default());
            let fact = attention::attention_factored(
                &q, &k, &v, &pq, &pk, &AttnOpts::default());
            fact.allclose(&dense, 1e-4, 1e-4)
        },
    );
}

#[test]
fn prop_online_softmax_block_size_invariant() {
    forall(
        Config::default().cases(25),
        |rng| {
            (
                gen_dim(rng, 1, 16),
                gen_dim(rng, 1, 40),
                gen_dim(rng, 2, 12),
                gen_dim(rng, 1, 41),
                rng.next_u64(),
            )
        },
        |_| vec![],
        |&(n, m, c, block, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let q = Tensor::randn(&[n, c], 1.0, &mut rng);
            let k = Tensor::randn(&[m, c], 1.0, &mut rng);
            let v = Tensor::randn(&[m, c], 1.0, &mut rng);
            let full = attention::attention(&q, &k, &v, None,
                                            &AttnOpts::default());
            let streamed = attention::online_softmax_attention(
                &q, &k, &v, None, block, &AttnOpts::default());
            streamed.allclose(&full, 1e-4, 1e-4)
        },
    );
}

#[test]
fn prop_alibi_exact_over_random_geometry() {
    forall(
        Config::default().cases(60),
        |rng| {
            (
                gen_dim(rng, 1, 80),
                gen_dim(rng, 1, 80),
                rng.uniform(0.001, 2.0) as f32,
            )
        },
        |_| vec![],
        |&(n, m, slope)| {
            let alibi = Alibi::new(n, m, slope);
            let (pq, pk) = alibi.factors();
            pq.matmul_t(&pk).allclose(&alibi.dense(), 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_spatial_exact_over_random_clouds() {
    forall(
        Config::default().cases(30),
        |rng| (gen_dim(rng, 1, 30), gen_dim(rng, 1, 30), rng.next_u64()),
        |_| vec![],
        |&(n, m, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let xq = Tensor::randn(&[n, 3], 1.0, &mut rng);
            let xk = Tensor::randn(&[m, 3], 1.0, &mut rng);
            let alpha: Vec<f32> =
                (0..n).map(|_| rng.uniform(0.1, 3.0) as f32).collect();
            let b = SpatialDistance::new(xq, xk, Some(alpha));
            let (pq, pk) = b.factors();
            pq.matmul_t(&pk).allclose(&b.dense(), 1e-3, 1e-3)
        },
    );
}

#[test]
fn prop_svd_error_matches_eckart_young() {
    // truncated-SVD error never beats and closely tracks the spectral
    // optimum
    forall(
        Config::default().cases(12),
        |rng| {
            (
                gen_dim(rng, 4, 24),
                gen_dim(rng, 4, 24),
                gen_dim(rng, 1, 8),
                rng.next_u64(),
            )
        },
        |_| vec![],
        |&(n, m, r, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let a = Tensor::randn(&[n, m], 1.0, &mut rng);
            let (pq, pk) = linalg::svd_factors(&a, r);
            let err = linalg::reconstruction_error(&a, &pq, &pk) as f64;
            let bound = linalg::eckart_young_error(&a, r);
            err >= bound - 5e-3 && err <= bound + 5e-2
        },
    );
}

#[test]
fn prop_factored_storage_always_matches_formula() {
    use flashbias::decompose::from_exact;
    forall(
        Config::default().cases(40),
        |rng| (gen_dim(rng, 1, 100), gen_dim(rng, 1, 100)),
        |_| vec![],
        |&(n, m)| {
            let f = from_exact(&Alibi::new(n, m, 0.5));
            f.size_bytes() == (n + m) * 2 * 4
        },
    );
}
