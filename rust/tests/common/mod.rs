//! Shared helpers for artifact-dependent integration tests.
#![allow(dead_code)] // each test crate uses a subset

use std::sync::Arc;

use flashbias::runtime::Runtime;

/// `None` (→ test skips) when artifacts or the PJRT backend are
/// unavailable; run `make artifacts` on the accelerator image.
pub fn runtime() -> Option<Runtime> {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: PJRT artifacts unavailable ({e})");
            return None;
        }
    };
    // the client is lazy: probe it so stub builds skip instead of failing
    if rt.load("attn_pure_n256").is_err() {
        eprintln!("SKIP: PJRT backend unavailable");
        return None;
    }
    Some(rt)
}

/// [`runtime`], wrapped for the coordinator tests.
pub fn runtime_arc() -> Option<Arc<Runtime>> {
    runtime().map(Arc::new)
}
