//! Failure injection: the runtime and coordinator must degrade cleanly —
//! bad manifests, missing binaries, wrong-arity requests, and
//! backpressure must produce errors, not hangs or crashes, and the
//! worker pool must survive failed requests.

use std::time::Duration;

use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig,
};
use flashbias::runtime::{HostValue, Runtime};
use flashbias::tensor::Tensor;

mod common;
use common::runtime_arc as runtime;

#[test]
fn open_missing_dir_errors() {
    let err = match Runtime::open("/nonexistent/path/xyz") {
        Ok(_) => panic!("open of missing dir must fail"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("manifest") || msg.contains("artifacts"),
            "unhelpful error: {msg}");
}

#[test]
fn open_corrupt_manifest_errors() {
    let dir = std::env::temp_dir().join("flashbias_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Runtime::open(&dir).is_err());
    // structurally valid JSON but missing fields
    std::fs::write(dir.join("manifest.json"), r#"{"format": 1}"#).unwrap();
    assert!(Runtime::open(&dir).is_err());
}

#[test]
fn manifest_with_missing_binaries_errors_on_read() {
    let dir = std::env::temp_dir().join("flashbias_missing_bins");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 1, "artifacts": [{"name": "ghost",
            "hlo": "hlo/ghost.hlo.txt",
            "inputs": [{"shape": [2], "dtype": "f32",
                        "file": "inputs/ghost/0.bin"}],
            "outputs": [], "meta": {}}]}"#,
    )
    .unwrap();
    let rt = Runtime::open(&dir).unwrap();
    assert!(rt.spec("ghost").is_some());
    assert!(rt.example_inputs("ghost").is_err(), "missing bin must error");
    assert!(rt.load("ghost").is_err(), "missing hlo must error");
}

#[test]
fn wrong_size_binary_rejected() {
    let dir = std::env::temp_dir().join("flashbias_badsize");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("inputs/x")).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": 1, "artifacts": [{"name": "x",
            "hlo": "hlo/x.hlo.txt",
            "inputs": [{"shape": [4], "dtype": "f32",
                        "file": "inputs/x/0.bin"}],
            "outputs": [], "meta": {}}]}"#,
    )
    .unwrap();
    std::fs::write(dir.join("inputs/x/0.bin"), [0u8; 8]).unwrap(); // 2 not 4
    let rt = Runtime::open(&dir).unwrap();
    let err = rt.example_inputs("x").unwrap_err();
    assert!(format!("{err:#}").contains("expected"));
}

#[test]
fn executable_rejects_wrong_arity_and_pool_survives() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("attn_pure_n256").unwrap();
    let good = rt.example_inputs("attn_pure_n256").unwrap();
    // wrong arity
    assert!(exe.run(&good[..2]).is_err());
    // still usable afterwards
    assert!(exe.run(&good).is_ok());
}

#[test]
fn coordinator_reports_failed_requests_and_continues() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
            queue_depth: 16,
        },
    );
    // a request with wrong-shaped inputs: PJRT must error, the worker
    // must survive, and the next good request must succeed
    let bad = vec![
        HostValue::F32(Tensor::zeros(&[1, 1])),
        HostValue::F32(Tensor::zeros(&[1, 1])),
        HostValue::F32(Tensor::zeros(&[1, 1])),
    ];
    coord.submit("attn_pure_n256", bad).unwrap();
    coord.flush_all().unwrap();
    let resp = coord.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(resp.outputs.is_err(), "mis-shaped request must fail");

    let good = rt.example_inputs("attn_pure_n256").unwrap();
    coord.submit("attn_pure_n256", good).unwrap();
    coord.flush_all().unwrap();
    let resp = coord.recv_timeout(Duration::from_secs(60)).unwrap();
    assert!(resp.outputs.is_ok(), "pool must survive a failed request");
    assert_eq!(coord.metrics().failed(), 1);
    assert_eq!(coord.metrics().completed(), 1);
    coord.shutdown();
}

#[test]
fn backpressure_surfaces_as_error_not_hang() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 1, // every submit flushes a batch
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
            queue_depth: 1,
        },
    );
    let inputs = rt.example_inputs("attn_dense_n512").unwrap();
    // slam the queue; with depth 1 and slow executes, some submit must
    // eventually report backpressure
    let mut saw_backpressure = false;
    let mut accepted = 0usize;
    for _ in 0..16 {
        match coord.submit("attn_dense_n512", inputs.clone()) {
            Ok(_) => accepted += 1,
            Err(e) => {
                saw_backpressure = true;
                assert!(format!("{e}").contains("backpressure"));
                break;
            }
        }
    }
    assert!(saw_backpressure, "queue_depth=1 should backpressure");
    // drain what was accepted
    let mut drained = 0usize;
    while drained < accepted {
        if coord
            .recv_timeout(Duration::from_secs(60))
            .is_some()
        {
            drained += 1;
        } else {
            break;
        }
    }
    coord.shutdown();
}

#[test]
fn shutdown_drains_inflight_work() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(rt.clone(),
                                     CoordinatorConfig::default());
    let inputs = rt.example_inputs("attn_pure_n256").unwrap();
    for _ in 0..3 {
        coord.submit("attn_pure_n256", inputs.clone()).unwrap();
    }
    coord.flush_all().unwrap();
    // shutdown without receiving: must not deadlock
    coord.shutdown();
}
