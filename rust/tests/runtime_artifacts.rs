//! Integration: the PJRT runtime replays every AOT artifact and matches
//! the outputs recorded by the python side at lowering time — the
//! L1/L2 ⇄ L3 integrity check. Requires `make artifacts`.

use flashbias::runtime::HostValue;

mod common;
use common::runtime;

fn max_diff(a: &[HostValue], b: &[HostValue]) -> f32 {
    let mut worst = 0.0f32;
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        match (x, y) {
            (HostValue::F32(tx), HostValue::F32(ty)) => {
                assert_eq!(tx.shape(), ty.shape());
                worst = worst.max(tx.sub(ty).max_abs());
            }
            (HostValue::I32(vx, _), HostValue::I32(vy, _)) => {
                assert_eq!(vx, vy);
            }
            _ => panic!("output dtype mismatch"),
        }
    }
    worst
}

#[test]
fn manifest_loads_and_has_expected_families() {
    let Some(rt) = runtime() else { return };
    let names = rt.names();
    assert!(names.len() >= 40, "only {} artifacts", names.len());
    for family in ["attn", "causal", "plain", "gpt2", "swin", "pde",
                   "pairformer", "fig5", "mult"] {
        assert!(
            names.iter().any(|n| rt.spec(n).unwrap().family() == family
                             || rt.spec(n).unwrap().family()
                                 .starts_with(family)),
            "no artifacts for family {family}"
        );
    }
}

#[test]
fn replay_micro_attention_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in ["attn_pure_n256", "attn_dense_n256", "attn_factored_n256",
                 "attn_flexlike_n256"] {
        let exe = rt.load(name).unwrap();
        let inputs = rt.example_inputs(name).unwrap();
        let expected = rt.expected_outputs(name).unwrap();
        let got = exe.run(&inputs).unwrap();
        let diff = max_diff(&got, &expected);
        assert!(diff < 1e-4, "{name}: max|Δ| = {diff}");
    }
}

#[test]
fn replay_causal_and_mult_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in ["causal_pure_n256", "causal_alibi_dense_n256",
                 "causal_alibi_factored_n256", "causal_alibi_jit_n256",
                 "mult_factored_n256", "mult_dense_n256"] {
        let exe = rt.load(name).unwrap();
        let got = exe.run(&rt.example_inputs(name).unwrap()).unwrap();
        let diff = max_diff(&got, &rt.expected_outputs(name).unwrap());
        assert!(diff < 1e-4, "{name}: max|Δ| = {diff}");
    }
}

#[test]
fn replay_model_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in ["plain_factored_n256", "gpt2_factored_n256",
                 "swin_factored", "pde_factored_n512",
                 "pairformer_neural"] {
        let exe = rt.load(name).unwrap();
        let got = exe.run(&rt.example_inputs(name).unwrap()).unwrap();
        let diff = max_diff(&got, &rt.expected_outputs(name).unwrap());
        assert!(diff < 2e-3, "{name}: max|Δ| = {diff}");
    }
}

#[test]
fn alibi_exact_decomposition_identical_through_models() {
    // Table 3's claim "the result of FlashBias is exactly equivalent":
    // gpt2_dense and gpt2_factored share weights and tokens; ALiBi's
    // exact decomposition must give (near-)identical logits end-to-end.
    let Some(rt) = runtime() else { return };
    let dense = rt
        .load("gpt2_dense_n256")
        .unwrap()
        .run(&rt.example_inputs("gpt2_dense_n256").unwrap())
        .unwrap();
    let fact = rt
        .load("gpt2_factored_n256")
        .unwrap()
        .run(&rt.example_inputs("gpt2_factored_n256").unwrap())
        .unwrap();
    let diff = max_diff(&dense, &fact);
    assert!(diff < 5e-3, "gpt2 dense vs factored: max|Δ| = {diff}");
}

#[test]
fn causal_alibi_variants_agree() {
    // dense / factored / jit all encode the same ALiBi bias over the same
    // q/k/v (same data seed) — outputs must agree.
    let Some(rt) = runtime() else { return };
    let run = |name: &str| {
        rt.load(name)
            .unwrap()
            .run(&rt.example_inputs(name).unwrap())
            .unwrap()
    };
    let dense = run("causal_alibi_dense_n256");
    let fact = run("causal_alibi_factored_n256");
    let jit = run("causal_alibi_jit_n256");
    assert!(max_diff(&dense, &fact) < 1e-3);
    assert!(max_diff(&dense, &jit) < 1e-3);
}

#[test]
fn fig5_pallas_and_sdpa_agree() {
    // Figure 5 compares two implementations of the same computation.
    let Some(rt) = runtime() else { return };
    let run = |name: &str| {
        rt.load(name)
            .unwrap()
            .run(&rt.example_inputs(name).unwrap())
            .unwrap()
    };
    let pallas = run("fig5_pallas_n256");
    let sdpa = run("fig5_sdpa_n256");
    assert!(max_diff(&pallas, &sdpa) < 1e-3);
}

#[test]
fn swin_svd_truncation_accuracy_preserved() {
    // Table 4: SVD-factored Swin must track the dense model closely
    // (class logits, not bit-exact — R=16 keeps ≥99% energy).
    let Some(rt) = runtime() else { return };
    let dense = rt
        .load("swin_dense")
        .unwrap()
        .run(&rt.example_inputs("swin_dense").unwrap())
        .unwrap();
    let fact = rt
        .load("swin_factored")
        .unwrap()
        .run(&rt.example_inputs("swin_factored").unwrap())
        .unwrap();
    let (d, f) = (dense[0].as_f32().unwrap(), fact[0].as_f32().unwrap());
    let rel = f.rel_err(d);
    assert!(rel < 0.15, "swin factored rel err {rel}");
    // top-1 class unchanged
    let argmax = |t: &flashbias::tensor::Tensor| {
        t.data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    assert_eq!(argmax(d), argmax(f));
}

#[test]
fn runtime_rejects_bad_requests() {
    let Some(rt) = runtime() else { return };
    assert!(rt.load("no_such_artifact").is_err());
    assert!(rt.example_inputs("no_such_artifact").is_err());
    let exe = rt.load("attn_pure_n256").unwrap();
    // wrong arity
    assert!(exe.run(&[]).is_err());
}

#[test]
fn executable_cache_returns_same_instance() {
    let Some(rt) = runtime() else { return };
    let a = rt.load("attn_pure_n256").unwrap();
    let b = rt.load("attn_pure_n256").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}
