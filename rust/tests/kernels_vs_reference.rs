//! Property tests: the tiled multi-threaded kernel engine against the
//! dense reference `attention()` — across all four `ExecMode`s, causal
//! and non-causal, ragged N≠M, and block sizes that do not divide N/M.

use flashbias::attention::{self, AttnOpts};
use flashbias::bias::{Alibi, ExactBias};
use flashbias::iomodel::Geometry;
use flashbias::kernels::{
    self, AlibiTile, BiasTile, DenseTile, FactoredTile, KernelConfig,
    NoBias,
};
use flashbias::plan::{
    BiasSpec, ExecMode, HostExecutor, Executor, PlanOptions, Planner,
    SimExecutor,
};
use flashbias::proplite::{forall, gen_dim, Config};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn qkv(n: usize, m: usize, c: usize,
       rng: &mut Xoshiro256) -> (Tensor, Tensor, Tensor) {
    (
        Tensor::randn(&[n, c], 1.0, rng),
        Tensor::randn(&[m, c], 1.0, rng),
        Tensor::randn(&[m, c], 1.0, rng),
    )
}

/// Engine vs dense-reference oracle over random geometry, provider kind,
/// causality, and non-dividing block sizes.
#[test]
fn prop_tiled_engine_matches_reference() {
    forall(
        Config::default().cases(60),
        |rng| {
            (
                gen_dim(rng, 1, 24),  // n
                gen_dim(rng, 1, 28),  // m (ragged vs n)
                gen_dim(rng, 2, 10),  // c
                gen_dim(rng, 1, 9),   // block_q (need not divide n)
                gen_dim(rng, 1, 11),  // block_k (need not divide m)
                rng.next_below(2) == 0, // causal
                rng.next_below(4),    // provider kind
                rng.next_u64(),       // data seed
            )
        },
        |_| vec![],
        |&(n, m, c, bq, bk, causal, kind, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let (q, k, v) = qkv(n, m, c, &mut rng);
            let cfg = KernelConfig::default()
                .with_blocks(bq, bk)
                .with_threads(1 + (seed % 4) as usize);
            let opts = AttnOpts { causal };
            let (tiled, reference) = match kind {
                0 => (
                    kernels::attention_tiled(&q, &k, &v, &NoBias, causal,
                                             &cfg),
                    attention::attention(&q, &k, &v, None, &opts),
                ),
                1 => {
                    let bias = Tensor::randn(&[n, m], 1.0, &mut rng);
                    (
                        kernels::attention_tiled(
                            &q, &k, &v, &DenseTile::from_tensor(&bias),
                            causal, &cfg),
                        attention::attention(&q, &k, &v, Some(&bias),
                                             &opts),
                    )
                }
                2 => {
                    let r = 1 + (seed % 4) as usize;
                    let pq = Tensor::randn(&[n, r], 0.4, &mut rng);
                    let pk = Tensor::randn(&[m, r], 0.4, &mut rng);
                    let dense = pq.matmul_t(&pk);
                    (
                        kernels::attention_tiled(
                            &q, &k, &v, &FactoredTile::new(&pq, &pk),
                            causal, &cfg),
                        attention::attention(&q, &k, &v, Some(&dense),
                                             &opts),
                    )
                }
                _ => {
                    let slope = 0.03125 * (1 + seed % 8) as f32;
                    let dense = Alibi::new(n, m, slope).dense();
                    (
                        kernels::attention_tiled(
                            &q, &k, &v, &AlibiTile { slope }, causal,
                            &cfg),
                        attention::attention(&q, &k, &v, Some(&dense),
                                             &opts),
                    )
                }
            };
            tiled.allclose(&reference, 1e-4, 1e-4)
        },
    );
}

/// The plan pipeline end-to-end: every `ExecMode` the planner can emit,
/// executed on host and simulator backends, against the oracle built
/// from the plan's own materialized bias.
#[test]
fn all_exec_modes_route_through_engine_and_match() {
    let (n, m, c) = (20, 26, 8);
    let geo = Geometry {
        n,
        m,
        c,
        r: 0,
        sram: 100 * 1024 / 2,
    };
    let planner = Planner::default();
    let mut rng = Xoshiro256::new(42);
    let (q, k, v) = qkv(n, m, c, &mut rng);
    // full-rank random table → DenseFallback; alibi → Factored (exact);
    // alibi + prefer_jit → Jit; None → NoBias
    let table = Tensor::randn(&[n, m], 1.0, &mut rng);
    let cases: Vec<(&str, BiasSpec, bool)> = vec![
        ("nobias", BiasSpec::None, false),
        ("factored", BiasSpec::alibi(n, m, 0.25), false),
        ("jit", BiasSpec::alibi(n, m, 0.25), true),
        ("dense", BiasSpec::dense(table), false),
    ];
    for causal in [false, true] {
        for (label, spec, prefer_jit) in &cases {
            let plan = planner
                .plan(
                    spec,
                    &geo,
                    &PlanOptions {
                        causal,
                        prefer_jit: *prefer_jit,
                        ..PlanOptions::default()
                    },
                )
                .expect("plan");
            match (*label, &plan.mode) {
                ("nobias", ExecMode::NoBias)
                | ("factored", ExecMode::Factored { .. })
                | ("jit", ExecMode::Jit { .. })
                | ("dense", ExecMode::Dense { .. }) => {}
                (l, mode) => panic!("{l}: unexpected mode {mode:?}"),
            }
            let oracle = attention::attention(
                &q,
                &k,
                &v,
                plan.materialized_bias().as_ref(),
                &AttnOpts { causal },
            );
            let host = HostExecutor.execute(&plan, &q, &k, &v).unwrap();
            assert!(host.allclose(&oracle, 1e-4, 1e-4),
                    "host {label} causal={causal}");
            let sim = SimExecutor::default();
            let simed = sim.execute(&plan, &q, &k, &v).unwrap();
            assert!(simed.allclose(&oracle, 1e-4, 1e-4),
                    "sim {label} causal={causal}");
            assert!(sim.last_report().expect("report").hbm_total() > 0);
        }
    }
}

/// Satellite regression: the streamed path must honor causal masking
/// (it used to take no `AttnOpts` and silently ignore it) and agree
/// with the reference for every block size.
#[test]
fn online_softmax_causal_regression() {
    let mut rng = Xoshiro256::new(3);
    for (n, m) in [(8, 8), (5, 9), (9, 5)] {
        let (q, k, v) = qkv(n, m, 6, &mut rng);
        let opts = AttnOpts { causal: true };
        let reference = attention::attention(&q, &k, &v, None, &opts);
        for block_k in [1, 2, 3, 7, 64] {
            let streamed = attention::online_softmax_attention(
                &q, &k, &v, None, block_k, &opts);
            assert!(
                streamed.allclose(&reference, 1e-5, 1e-5),
                "n={n} m={m} block_k={block_k}"
            );
        }
    }
}

/// Satellite regression: fully-masked rows (decoder alignment, N > M)
/// are exactly zero in the reference, the engine, and the streamed
/// wrapper — not a uniform average over masked keys.
#[test]
fn fully_masked_rows_zero_everywhere() {
    let mut rng = Xoshiro256::new(4);
    let (n, m, c) = (10, 6, 4);
    let (q, k, v) = qkv(n, m, c, &mut rng);
    let opts = AttnOpts { causal: true };
    let reference = attention::attention(&q, &k, &v, None, &opts);
    let tiled = kernels::attention_tiled(
        &q, &k, &v, &NoBias, true,
        &KernelConfig::default().with_blocks(3, 2));
    let streamed =
        attention::online_softmax_attention(&q, &k, &v, None, 4, &opts);
    for out in [&reference, &tiled, &streamed] {
        for i in 0..n - m {
            assert!(out.row(i).iter().all(|&x| x == 0.0),
                    "row {i} not zero");
        }
    }
    assert!(tiled.allclose(&reference, 1e-5, 1e-5));
    assert!(streamed.allclose(&reference, 1e-5, 1e-5));
}

/// The batched `(B, H, N, C)` entry matches per-program single calls.
#[test]
fn prop_batched_entry_matches_single_calls() {
    forall(
        Config::default().cases(25),
        |rng| {
            (
                gen_dim(rng, 1, 3),  // b
                gen_dim(rng, 1, 3),  // h
                gen_dim(rng, 2, 10), // n
                gen_dim(rng, 2, 12), // m
                gen_dim(rng, 2, 6),  // c
                rng.next_below(2) == 0,
                rng.next_u64(),
            )
        },
        |_| vec![],
        |&(b, h, n, m, c, causal, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let q = Tensor::randn(&[b, h, n, c], 1.0, &mut rng);
            let k = Tensor::randn(&[b, h, m, c], 1.0, &mut rng);
            let v = Tensor::randn(&[b, h, m, c], 1.0, &mut rng);
            let tile = AlibiTile { slope: 0.125 };
            let cfg = KernelConfig::default().with_blocks(3, 4);
            let out = kernels::attention_batched(&q, &k, &v, &tile,
                                                 causal, &cfg);
            if out.shape() != &[b, h, n, c][..] {
                return false;
            }
            (0..b * h).all(|pi| {
                let single = kernels::attention_tiled(
                    &q.view_slab(pi).to_tensor(),
                    &k.view_slab(pi).to_tensor(),
                    &v.view_slab(pi).to_tensor(),
                    &tile,
                    causal,
                    &cfg,
                );
                out.view_slab(pi)
                    .to_tensor()
                    .allclose(&single, 0.0, 0.0)
            })
        },
    );
}

/// Providers report the Thm 3.2 bias residency the plan claims.
#[test]
fn provider_residency_matches_plan_storage() {
    let (n, m, c) = (32, 32, 8);
    let geo = Geometry {
        n,
        m,
        c,
        r: 0,
        sram: 100 * 1024 / 2,
    };
    let planner = Planner::default();
    for (spec, jit) in [
        (BiasSpec::alibi(n, m, 0.5), false),
        (BiasSpec::alibi(n, m, 0.5), true),
        (BiasSpec::None, false),
    ] {
        let plan = planner
            .plan(
                &spec,
                &geo,
                &PlanOptions {
                    prefer_jit: jit,
                    ..PlanOptions::default()
                },
            )
            .unwrap();
        let tile = flashbias::plan::plan_bias_tile(&plan);
        assert_eq!(
            tile.resident_elems() * 4,
            plan.bias_storage_bytes,
            "{spec:?} jit={jit}"
        );
    }
}
