//! Host-plan serving: the coordinator executes flushed batches as one
//! batched kernel-engine call — no PJRT artifacts needed, so this is
//! tier-1 coverage of the router→batcher→worker→engine path.

use std::sync::Arc;
use std::time::Duration;

use flashbias::attention::{self, AttnOpts};
use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig,
};
use flashbias::iomodel::Geometry;
use flashbias::plan::{AttentionPlan, BiasSpec, PlanOptions, Planner};
use flashbias::runtime::{HostValue, Runtime};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

const N: usize = 24;
const M: usize = 24;
const C: usize = 8;
const H: usize = 2;

fn alibi_plan(causal: bool) -> AttentionPlan {
    Planner::default()
        .plan(
            &BiasSpec::alibi(N, M, 0.25),
            &Geometry {
                n: N,
                m: M,
                c: C,
                r: 0,
                sram: 100 * 1024 / 2,
            },
            &PlanOptions {
                causal,
                ..PlanOptions::default()
            },
        )
        .expect("plan")
}

fn coordinator() -> Coordinator {
    Coordinator::new(
        Arc::new(Runtime::empty()),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_depth: 64,
        },
    )
}

fn request_inputs(seed: u64) -> (Vec<HostValue>, Tensor, Tensor, Tensor) {
    let mut rng = Xoshiro256::new(seed);
    let q = Tensor::randn(&[H, N, C], 1.0, &mut rng);
    let k = Tensor::randn(&[H, M, C], 1.0, &mut rng);
    let v = Tensor::randn(&[H, M, C], 1.0, &mut rng);
    (
        vec![
            HostValue::F32(q.clone()),
            HostValue::F32(k.clone()),
            HostValue::F32(v.clone()),
        ],
        q,
        k,
        v,
    )
}

#[test]
fn batched_engine_serving_matches_reference() {
    let plan = alibi_plan(true);
    let bias = plan.materialized_bias().expect("alibi bias");
    let mut coord = coordinator();
    coord.register_plan("alibi_host_n24", plan).expect("register");

    let mut payloads = Vec::new();
    let mut reqs = Vec::new();
    for i in 0..10u64 {
        let (inputs, q, k, v) = request_inputs(100 + i);
        payloads.push((q, k, v));
        reqs.push(("alibi_host_n24".to_string(), inputs));
    }
    let responses = coord.run_burst(reqs).expect("burst");
    assert_eq!(responses.len(), 10);
    for resp in &responses {
        let outs = resp.outputs.as_ref().expect("engine output");
        let got = outs[0].as_f32().expect("f32 output");
        assert_eq!(got.shape(), &[H, N, C]);
        let (q, k, v) = &payloads[resp.id as usize];
        for h in 0..H {
            let reference = attention::attention(
                &q.index0(h),
                &k.index0(h),
                &v.index0(h),
                Some(&bias),
                &AttnOpts { causal: true },
            );
            assert!(
                got.index0(h).allclose(&reference, 1e-4, 1e-4),
                "resp {} head {h}",
                resp.id
            );
        }
    }
    let m = coord.metrics();
    assert_eq!(m.submitted(), 10);
    assert_eq!(m.completed(), 10);
    coord.shutdown();
}

#[test]
fn rank2_payloads_are_served() {
    let plan = alibi_plan(false);
    let bias = plan.materialized_bias().expect("alibi bias");
    let mut coord = coordinator();
    coord.register_plan("alibi_flat", plan).expect("register");
    let mut rng = Xoshiro256::new(7);
    let q = Tensor::randn(&[N, C], 1.0, &mut rng);
    let k = Tensor::randn(&[M, C], 1.0, &mut rng);
    let v = Tensor::randn(&[M, C], 1.0, &mut rng);
    let inputs = vec![
        HostValue::F32(q.clone()),
        HostValue::F32(k.clone()),
        HostValue::F32(v.clone()),
    ];
    let responses = coord
        .run_burst(vec![("alibi_flat".to_string(), inputs)])
        .expect("burst");
    let got = responses[0].outputs.as_ref().expect("output")[0]
        .as_f32()
        .expect("f32")
        .clone();
    assert_eq!(got.shape(), &[N, C]);
    let reference = attention::attention(&q, &k, &v, Some(&bias),
                                         &AttnOpts::default());
    assert!(got.allclose(&reference, 1e-4, 1e-4));
    coord.shutdown();
}

#[test]
fn mixed_rank_batch_serves_both_groups() {
    // a rank-2 and a rank-3 request for the same plan land in one
    // flushed batch; the worker stacks them as separate signature
    // groups and both succeed
    let plan = alibi_plan(false);
    let bias = plan.materialized_bias().expect("alibi bias");
    let mut coord = coordinator();
    coord.register_plan("alibi_mixed", plan).expect("register");
    let mut rng = Xoshiro256::new(21);
    let q2 = Tensor::randn(&[N, C], 1.0, &mut rng);
    let k2 = Tensor::randn(&[M, C], 1.0, &mut rng);
    let v2 = Tensor::randn(&[M, C], 1.0, &mut rng);
    let flat = vec![
        HostValue::F32(q2.clone()),
        HostValue::F32(k2.clone()),
        HostValue::F32(v2.clone()),
    ];
    let (headed, q3, k3, v3) = request_inputs(22);
    let responses = coord
        .run_burst(vec![
            ("alibi_mixed".to_string(), flat),
            ("alibi_mixed".to_string(), headed),
        ])
        .expect("burst");
    assert_eq!(responses.len(), 2);
    let flat_out = responses[0].outputs.as_ref().expect("rank-2 ok")[0]
        .as_f32()
        .expect("f32");
    let ref_flat = attention::attention(&q2, &k2, &v2, Some(&bias),
                                        &AttnOpts::default());
    assert!(flat_out.allclose(&ref_flat, 1e-4, 1e-4));
    let headed_out = responses[1].outputs.as_ref().expect("rank-3 ok")[0]
        .as_f32()
        .expect("f32");
    for h in 0..H {
        let reference = attention::attention(
            &q3.index0(h),
            &k3.index0(h),
            &v3.index0(h),
            Some(&bias),
            &AttnOpts::default(),
        );
        assert!(headed_out.index0(h).allclose(&reference, 1e-4, 1e-4));
    }
    coord.shutdown();
}

#[test]
fn bad_payload_gets_error_response_not_hang() {
    let mut coord = coordinator();
    coord.register_plan("alibi_err", alibi_plan(false)).expect("register");
    let mut rng = Xoshiro256::new(8);
    // wrong N: 12 instead of 24
    let q = Tensor::randn(&[12, C], 1.0, &mut rng);
    let k = Tensor::randn(&[M, C], 1.0, &mut rng);
    let v = Tensor::randn(&[M, C], 1.0, &mut rng);
    let inputs = vec![
        HostValue::F32(q),
        HostValue::F32(k),
        HostValue::F32(v),
    ];
    let responses = coord
        .run_burst(vec![("alibi_err".to_string(), inputs)])
        .expect("burst completes");
    assert_eq!(responses.len(), 1);
    assert!(responses[0].outputs.is_err(), "shape mismatch must error");
    coord.shutdown();
}

#[test]
fn unknown_artifact_still_rejected() {
    let mut coord = coordinator();
    assert!(coord.submit("nope", vec![]).is_err());
    coord.shutdown();
}

#[test]
fn submit_errors_are_typed_for_retry_decisions() {
    use flashbias::coordinator::SubmitError;
    let mut coord = coordinator();
    match coord.try_submit("nope", vec![]) {
        Err(SubmitError::UnknownArtifact(name)) => {
            assert_eq!(name, "nope")
        }
        other => panic!("expected UnknownArtifact, got {other:?}"),
    }
    assert!(!SubmitError::UnknownArtifact("x".into()).is_backpressure());
    let bp = SubmitError::Backpressure { inputs: vec![] };
    assert!(bp.is_backpressure());
    // the anyhow wrapper keeps the backpressure marker visible for
    // string-matching callers
    assert!(format!("{bp}").contains("backpressure"));
    coord.shutdown();
}

#[test]
fn submit_retry_propagates_non_backpressure_errors() {
    // the serving loop's retry used to spin forever on ANY submit
    // error, including "unknown artifact" — it must fail fast instead
    let mut coord = coordinator();
    let t0 = std::time::Instant::now();
    let err = flashbias::server::submit_with_retry(
        &mut coord,
        "no_such_artifact",
        vec![],
        |_| {},
    )
    .expect_err("unknown artifact must propagate");
    assert!(format!("{err}").contains("no_such_artifact"));
    assert!(t0.elapsed() < Duration::from_secs(5),
            "must not spin on a non-retryable error");
    coord.shutdown();
}

#[test]
fn backpressure_retry_accounts_for_every_response() {
    // queue_depth=1 + max_batch=1 + 1 worker: submits outrun the
    // queue, so submit_with_retry must absorb refusals by draining —
    // and every drained response must still be accounted for
    let plan = alibi_plan(false);
    let mut coord = Coordinator::new(
        Arc::new(Runtime::empty()),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
            queue_depth: 1,
        },
    );
    coord.register_plan("alibi_bp", plan).expect("register");
    let total = 24u64;
    let mut drained = 0usize;
    for i in 0..total {
        let (inputs, _, _, _) = request_inputs(400 + i);
        flashbias::server::submit_with_retry(
            &mut coord,
            "alibi_bp",
            inputs,
            |resp| {
                assert!(resp.outputs.is_ok());
                drained += 1;
            },
        )
        .expect("backpressure is retryable");
    }
    coord.flush_all().expect("flush");
    let mut completed = drained;
    while completed < total as usize {
        match coord.recv_timeout(Duration::from_secs(30)) {
            Some(resp) => {
                assert!(resp.outputs.is_ok());
                completed += 1;
            }
            None => panic!(
                "lost responses: {completed}/{total} (drained {drained})"
            ),
        }
    }
    coord.shutdown();
}
