//! The tiled-execution simulator must agree with the analytic IO model
//! (Theorems 3.1/3.2, Corollaries 3.3/3.7) up to block-rounding constants
//! — over wide sweeps of N, C, R and SRAM size.

use flashbias::iomodel::{self, Geometry};
use flashbias::simulator::{simulate_fwd, Algorithm, HwModel};

fn hw(sram: usize) -> HwModel {
    HwModel {
        sram_elems: sram,
        ..HwModel::default()
    }
}

/// Ratio spread of simulated/model over a sweep must stay bounded — that
/// is what Θ(...) agreement means.
fn theta_stable(ratios: &[f64], max_spread: f64, label: &str) {
    let (lo, hi) = ratios
        .iter()
        .fold((f64::MAX, f64::MIN), |(l, h), &r| (l.min(r), h.max(r)));
    assert!(
        hi / lo <= max_spread,
        "{label}: ratios {ratios:?} spread {:.2} > {max_spread}",
        hi / lo
    );
}

#[test]
fn cor_3_7_flashbias_io_theta_over_n() {
    for &r in &[8usize, 16, 64] {
        let ratios: Vec<f64> = [512usize, 2048, 8192, 32768]
            .iter()
            .map(|&n| {
                let g = Geometry::square(n, 64, r, 51200);
                simulate_fwd(Algorithm::FlashBias(r), &g, &hw(51200))
                    .hbm_total() as f64
                    / iomodel::flashbias_io(&g)
            })
            .collect();
        theta_stable(&ratios, 1.7, &format!("flashbias R={r} over N"));
    }
}

#[test]
fn cor_3_7_flashbias_io_theta_over_sram() {
    // IO must scale ≈ 1/S
    let ratios: Vec<f64> = [16_384usize, 51_200, 131_072, 524_288]
        .iter()
        .map(|&s| {
            let g = Geometry::square(8192, 64, 16, s);
            simulate_fwd(Algorithm::FlashBias(16), &g, &hw(s)).hbm_total()
                as f64
                / iomodel::flashbias_io(&g)
        })
        .collect();
    theta_stable(&ratios, 2.5, "flashbias over S");
}

#[test]
fn dense_bias_io_theta_over_n() {
    let ratios: Vec<f64> = [512usize, 2048, 8192, 32768]
        .iter()
        .map(|&n| {
            let g = Geometry::square(n, 64, 64, 51200);
            simulate_fwd(Algorithm::FlashDenseBias, &g, &hw(51200))
                .hbm_total() as f64
                / iomodel::flash_dense_bias_io(&g)
        })
        .collect();
    theta_stable(&ratios, 1.7, "dense bias over N");
}

#[test]
fn flash_io_theta_over_channel() {
    let ratios: Vec<f64> = [32usize, 64, 128]
        .iter()
        .map(|&c| {
            let g = Geometry::square(8192, c, 0, 51200);
            simulate_fwd(Algorithm::Flash, &g, &hw(51200)).hbm_total() as f64
                / iomodel::flash_attention_io(&g)
        })
        .collect();
    // C² scaling has larger block-rounding wobble; still bounded
    theta_stable(&ratios, 3.0, "flash over C");
}

#[test]
fn lower_bound_never_beaten() {
    // Corollary 3.3: the simulator cannot beat the lower bound (up to the
    // block-allocation constant < 1 is impossible; allow 0.5 for the
    // Θ-constant mismatch direction)
    for n in [1024usize, 8192, 32768] {
        for r in [8usize, 64] {
            let g = Geometry::square(n, 64, r, 51200);
            let sim = simulate_fwd(Algorithm::FlashBias(r), &g, &hw(51200))
                .hbm_total() as f64;
            let bound = iomodel::lower_bound_io(&g);
            assert!(
                sim > bound * 0.5,
                "n={n} r={r}: simulated {sim} below lower bound {bound}"
            );
        }
    }
}

#[test]
fn thm_3_1_standard_over_flash_ratio_tracks_beta() {
    // doubling SRAM (β) roughly doubles the standard/flash IO ratio
    let g = |s| Geometry::square(8192, 64, 0, s);
    let ratio = |s: usize| {
        let std =
            simulate_fwd(Algorithm::Standard, &g(s), &hw(s)).hbm_total();
        let fla = simulate_fwd(Algorithm::Flash, &g(s), &hw(s)).hbm_total();
        std as f64 / fla as f64
    };
    let r1 = ratio(25_600);
    let r2 = ratio(51_200);
    let gain = r2 / r1;
    assert!((1.5..=2.5).contains(&gain), "β-scaling gain {gain}");
}

#[test]
fn thm_3_2_memory_footprints() {
    // simulator peak memory matches the storage model: dense ⇒ Θ(N²),
    // factored ⇒ Θ((N+M)R)
    for n in [2048usize, 8192] {
        let g = Geometry::square(n, 64, 16, 51200);
        let dense = simulate_fwd(Algorithm::FlashDenseBias, &g, &hw(51200));
        let fact = simulate_fwd(Algorithm::FlashBias(16), &g, &hw(51200));
        let dense_bias_bytes = dense.hbm_peak as i64
            - fact.hbm_peak as i64;
        let model_gap = iomodel::dense_storage_elems(n, n) as i64
            - iomodel::factored_storage_elems(n, n, 16) as i64;
        let rel = (dense_bias_bytes - model_gap).abs() as f64
            / model_gap as f64;
        assert!(rel < 0.2, "n={n}: peak gap {dense_bias_bytes} vs model \
                            {model_gap}");
    }
}

#[test]
fn figure4_efficiency_ratio_improves_with_n() {
    // Figure 4: FlashBias's advantage over dense-bias grows with sequence
    // length (the quadratic stream dominates)
    let hwm = hw(51200);
    let ratio = |n: usize| {
        let g = Geometry::square(n, 64, 16, 51200);
        let dense =
            simulate_fwd(Algorithm::FlashDenseBias, &g, &hwm).cost(&hwm);
        let fb = simulate_fwd(Algorithm::FlashBias(16), &g, &hwm).cost(&hwm);
        dense / fb
    };
    let r1k = ratio(1024);
    let r16k = ratio(16384);
    assert!(r16k >= r1k * 0.99, "ratio fell: 1k={r1k} 16k={r16k}");
    assert!(r16k > 1.3, "no speedup at 16k: {r16k}");
}
