//! Adversarial-input robustness for the jsonlite codec and the remote
//! frame protocol built on it: malformed documents, truncations, deep
//! nesting, non-finite floats, and hostile length prefixes must all
//! surface as typed errors — never a panic, stack overflow, or huge
//! allocation. Driven by proplite where the input space is worth
//! sampling.

use std::io::Cursor;

use flashbias::factorstore::remote::{read_frame_limited, write_frame};
use flashbias::jsonlite::{Json, MAX_DEPTH};
use flashbias::proplite::{forall, shrink_usize, Config};
use flashbias::util::Xoshiro256;

/// A corpus of valid documents to mutate.
const VALID_DOCS: &[&str] = &[
    "null",
    "true",
    "-12.5e3",
    "\"str with \\\"escapes\\\" and \\u00e9\"",
    "[1, 2, [3, null], {\"k\": false}]",
    "{\"version\": 1, \"entries\": [{\"key\": \"0xbeef\", \"rank\": 3, \
     \"phi_q\": [0.5, -1.25], \"rel_err\": 0.01}]}",
    "{}",
    "[]",
];

/// Random printable-ish mutation of a valid doc: truncate, flip bytes,
/// or splice. Always valid UTF-8 (parse takes &str).
fn mutate(rng: &mut Xoshiro256) -> String {
    let doc = VALID_DOCS[rng.next_below(VALID_DOCS.len() as u64) as usize];
    let mut bytes = doc.as_bytes().to_vec();
    match rng.next_below(3) {
        0 => {
            let cut = rng.next_below(bytes.len() as u64 + 1) as usize;
            bytes.truncate(cut);
        }
        1 => {
            for _ in 0..=rng.next_below(4) {
                if bytes.is_empty() {
                    break;
                }
                let at = rng.next_below(bytes.len() as u64) as usize;
                bytes[at] = b' ' + (rng.next_below(94) as u8); // printable
            }
        }
        _ => {
            let at = rng.next_below(bytes.len() as u64 + 1) as usize;
            let junk: &[u8] = [
                &b"{"[..], &b"]"[..], &b"\""[..], &b",,"[..], &b"1e"[..],
                &b"\\u"[..],
            ][rng.next_below(6) as usize];
            bytes.splice(at..at, junk.iter().copied());
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

#[test]
fn mutated_documents_never_panic_the_parser() {
    forall(
        Config::default().cases(2000).seed(0xA11),
        mutate,
        |_| Vec::new(), // any panic IS the failure; nothing to shrink
        |s| {
            // Ok or Err both fine — reaching a verdict is the property.
            let _ = Json::parse(s);
            true
        },
    );
}

#[test]
fn strict_prefixes_of_structural_docs_are_typed_errors() {
    let doc = VALID_DOCS[5]; // the nested store-file-shaped object
    forall(
        Config::default().cases(200),
        |rng| 1 + rng.next_below(doc.len() as u64 - 1) as usize,
        shrink_usize,
        |&cut| {
            // A structural doc's closing brace is its last byte, so
            // every strict prefix must fail with a ParseError…
            let err = match Json::parse(&doc[..cut]) {
                Err(e) => e,
                Ok(v) => panic!("prefix of {cut} bytes parsed as {v:?}"),
            };
            // …that points inside the input and renders.
            err.pos <= cut && !err.to_string().is_empty()
        },
    );
}

#[test]
fn nesting_is_capped_exactly_at_max_depth() {
    let nested = |d: usize| format!("{}0{}", "[".repeat(d), "]".repeat(d));
    assert!(Json::parse(&nested(MAX_DEPTH)).is_ok());
    let err = Json::parse(&nested(MAX_DEPTH + 1)).expect_err("over the cap");
    assert!(err.msg.contains("nesting"), "{err}");
    // Mixed object/array nesting counts every level.
    let mixed = format!(
        "{}0{}",
        "[{\"k\":".repeat(MAX_DEPTH / 2 + 1),
        "}]".repeat(MAX_DEPTH / 2 + 1)
    );
    assert!(Json::parse(&mixed).is_err());
}

#[test]
fn unclosed_deep_nesting_cannot_blow_the_stack() {
    // Without the depth cap this recursed ~200k frames deep. The cap
    // must reject it as a parse error, not a crash.
    for pattern in ["[", "[0,", "{\"k\":"] {
        let hostile = pattern.repeat(200_000);
        assert!(Json::parse(&hostile).is_err(), "pattern {pattern:?}");
    }
}

#[test]
fn known_nasty_inputs_error_without_panicking() {
    let nasty = [
        "", " ", "\t\n", "nul", "tru", "falsehood", "-", "+1",
        ".5", "--1", "0x10", "1e", "1e+", "\"unterminated", "\"\\", "\"\\q\"",
        "\"\\u12\"", "\"\\uZZZZ\"", "{", "}", "[", "]", "[1,]", "[,1]",
        "{\"a\"}", "{\"a\":}", "{:1}", "{1:2}", "{\"a\":1,}", "[1 2]",
        "1 2", "null null", "\u{0}",
    ];
    for s in nasty {
        assert!(Json::parse(s).is_err(), "expected error for {s:?}");
    }
    // Absurd exponents and digit runs must resolve (to a finite or
    // infinite f64) without panicking; which verdict is unspecified.
    let digits = "9".repeat(400);
    for s in ["1e999", "-1e999", digits.as_str()] {
        let _ = Json::parse(s);
    }
}

#[test]
fn non_finite_floats_dump_as_null_and_reparse() {
    for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        assert_eq!(Json::num(x).dump(), "null");
    }
    // The exec_p99-shaped failure: a metrics dump whose percentiles are
    // NaN (no samples yet) must still be a valid document end to end.
    let dump = Json::obj(vec![
        ("exec_p99_s", Json::num(f64::NAN)),
        ("queue_p50_s", Json::num(f64::INFINITY)),
        ("completed", Json::num(3.0)),
    ])
    .dump();
    let back = Json::parse(&dump).expect("must reparse");
    assert!(back.get("exec_p99_s").is_null());
    assert!(back.get("queue_p50_s").is_null());
    assert_eq!(back.get("completed").as_usize(), Some(3));
}

/// Random bounded-depth document generator for the roundtrip property.
fn gen_doc(rng: &mut Xoshiro256, depth: usize) -> Json {
    match rng.next_below(if depth == 0 { 4 } else { 6 }) {
        0 => Json::Null,
        1 => Json::Bool(rng.next_below(2) == 0),
        2 => {
            // finite floats only: non-finite intentionally dump as null
            let x = (rng.next_below(2_000_001) as f64 - 1_000_000.0) / 64.0;
            Json::Num(x)
        }
        3 => {
            let len = rng.next_below(8) as usize;
            Json::Str(
                (0..len)
                    .map(|_| {
                        char::from(b' ' + rng.next_below(94) as u8)
                    })
                    .collect(),
            )
        }
        4 => Json::Arr(
            (0..rng.next_below(4)).map(|_| gen_doc(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.next_below(4))
                .map(|i| (format!("k{i}"), gen_doc(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn dump_parse_roundtrip_is_identity() {
    forall(
        Config::default().cases(500).seed(0xD0C),
        |rng| gen_doc(rng, 4),
        |_| Vec::new(),
        |doc| Json::parse(&doc.dump()).map(|v| v == *doc).unwrap_or(false),
    );
}

// ---------------------------------------------------------------------------
// Remote frame codec: hostile length prefixes and torn frames
// ---------------------------------------------------------------------------

const TEST_CAP: u32 = 64 * 1024;

#[test]
fn hostile_length_prefix_is_rejected_before_allocation() {
    // 4 GiB announced, 4 bytes present: must fail on the cap check, not
    // by attempting the allocation or waiting for bytes.
    for announced in [TEST_CAP + 1, 1 << 30, u32::MAX] {
        let mut wire = announced.to_le_bytes().to_vec();
        wire.extend_from_slice(b"ha!!");
        let err = read_frame_limited(&mut Cursor::new(&wire), TEST_CAP)
            .expect_err("over-cap frame must be rejected");
        assert!(err.to_string().contains("limit"), "{err}");
    }
}

#[test]
fn torn_frames_error_and_clean_eof_is_none() {
    let mut wire = Vec::new();
    write_frame(
        &mut wire,
        &Json::obj(vec![("op", Json::str("get")), ("key", Json::str("0xbeef"))]),
    )
    .expect("write frame");
    let total = wire.len();
    assert!(total > 8);
    forall(
        Config::default().cases(200),
        |rng| rng.next_below(total as u64) as usize,
        shrink_usize,
        |&cut| {
            let torn = &wire[..cut];
            match read_frame_limited(&mut Cursor::new(torn), TEST_CAP) {
                // nothing-or-partial-prefix reads as clean EOF between
                // frames…
                Ok(None) => cut < 4,
                // …a full prefix with a torn payload is a hard error…
                Err(_) => cut >= 4,
                // …and a parse can never succeed short of the full frame.
                Ok(Some(v)) => panic!("torn frame at {cut} parsed: {v:?}"),
            }
        },
    );
}

#[test]
fn frame_roundtrip_under_the_request_cap() {
    let doc = Json::obj(vec![
        ("op", Json::str("get")),
        ("key", Json::str("0xffffffffffffffff")),
    ]);
    let mut wire = Vec::new();
    write_frame(&mut wire, &doc).expect("write");
    let back = read_frame_limited(&mut Cursor::new(&wire), TEST_CAP)
        .expect("read")
        .expect("one frame");
    assert_eq!(back, doc);
    // A second read on the drained stream is the clean-EOF case.
    let mut cur = Cursor::new(&wire);
    let _ = read_frame_limited(&mut cur, TEST_CAP).expect("read");
    assert!(read_frame_limited(&mut cur, TEST_CAP).expect("eof").is_none());
}

#[test]
fn non_utf8_frame_payload_is_a_typed_error() {
    let payload: &[u8] = &[0xFF, 0xFE, 0x80, 0x81];
    let mut wire = (payload.len() as u32).to_le_bytes().to_vec();
    wire.extend_from_slice(payload);
    let err = read_frame_limited(&mut Cursor::new(&wire), TEST_CAP)
        .expect_err("non-utf8 payload");
    assert!(err.to_string().contains("utf8"), "{err}");
}
