//! Integration: the full serving stack (router → batcher → worker pool →
//! PJRT) over real artifacts. Requires `make artifacts`.

use std::time::Duration;

use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouteKey, Router,
};
mod common;
use common::runtime_arc as runtime;

#[test]
fn router_builds_from_manifest() {
    let Some(rt) = runtime() else { return };
    let router = Router::from_runtime(&rt);
    assert!(!router.is_empty());
    let key = RouteKey::new("attn", "factored");
    let (name, bucket) = router.route(&key, 300).expect("route 300");
    assert_eq!(bucket, 512);
    assert_eq!(name, "attn_factored_n512");
    // exact fit
    assert_eq!(router.route(&key, 256).unwrap().1, 256);
    // oversize
    assert!(router.route(&key, 100_000).is_none());
}

#[test]
fn serve_burst_end_to_end() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_depth: 64,
        },
    );
    let inputs = rt.example_inputs("attn_factored_n256").unwrap();
    let reqs: Vec<_> = (0..10)
        .map(|_| ("attn_factored_n256".to_string(), inputs.clone()))
        .collect();
    let responses = coord.run_burst(reqs).unwrap();
    assert_eq!(responses.len(), 10);
    let expected = rt.expected_outputs("attn_factored_n256").unwrap();
    for resp in &responses {
        let outs = resp.outputs.as_ref().unwrap();
        let diff = outs[0]
            .as_f32()
            .unwrap()
            .sub(expected[0].as_f32().unwrap())
            .max_abs();
        assert!(diff < 1e-4, "resp {} diff {diff}", resp.id);
    }
    // metrics consistent
    let m = coord.metrics();
    assert_eq!(m.submitted(), 10);
    assert_eq!(m.completed(), 10);
    assert_eq!(m.failed(), 0);
    assert!(m.batches() >= 3); // 10 requests / max_batch 4
    assert!(m.mean_batch_size() <= 4.0);
    coord.shutdown();
}

#[test]
fn mixed_artifact_burst_routes_correctly() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(rt.clone(), CoordinatorConfig::default());
    let a = rt.example_inputs("attn_pure_n256").unwrap();
    let b = rt.example_inputs("attn_dense_n256").unwrap();
    let mut reqs = Vec::new();
    for _ in 0..3 {
        reqs.push(("attn_pure_n256".to_string(), a.clone()));
        reqs.push(("attn_dense_n256".to_string(), b.clone()));
    }
    let responses = coord.run_burst(reqs).unwrap();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert!(r.outputs.is_ok(), "{}: {:?}", r.artifact, r.outputs);
    }
    coord.shutdown();
}

#[test]
fn unknown_artifact_rejected_at_submit() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(rt, CoordinatorConfig::default());
    assert!(coord.submit("nope", vec![]).is_err());
    coord.shutdown();
}

#[test]
fn deadline_flush_drains_partial_batches() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 100, // never fills
                max_wait: Duration::from_millis(1),
            },
            workers: 1,
            queue_depth: 8,
        },
    );
    let inputs = rt.example_inputs("attn_pure_n256").unwrap();
    coord.submit("attn_pure_n256", inputs).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    coord.flush_due().unwrap();
    let resp = coord
        .recv_timeout(Duration::from_secs(60))
        .expect("deadline flush must deliver");
    assert!(resp.outputs.is_ok());
    assert!(resp.queue_time >= Duration::from_millis(1));
    coord.shutdown();
}

#[test]
fn queue_time_reflects_batch_wait() {
    let Some(rt) = runtime() else { return };
    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 2,
                max_wait: Duration::from_secs(10),
            },
            workers: 1,
            queue_depth: 8,
        },
    );
    let inputs = rt.example_inputs("attn_pure_n256").unwrap();
    coord.submit("attn_pure_n256", inputs.clone()).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    coord.submit("attn_pure_n256", inputs).unwrap(); // fills the batch
    let r1 = coord.recv_timeout(Duration::from_secs(60)).unwrap();
    let r2 = coord.recv_timeout(Duration::from_secs(60)).unwrap();
    let (first, second) = if r1.id == 0 { (r1, r2) } else { (r2, r1) };
    // the first request waited for the second
    assert!(first.queue_time >= Duration::from_millis(15),
            "queue_time {:?}", first.queue_time);
    assert!(second.queue_time < first.queue_time);
    coord.shutdown();
}
