//! Runtime complement to flashlint: hammer the serving core's shared
//! state from many threads with the util::sync audit compiled in, then
//! assert the observed lock-order graph is acyclic and that no lock was
//! held across a blocking region it does not own.
//!
//! The audit is global to the process, so the tests here serialize on
//! one gate and reset the audit state before each scenario.

use std::sync::Arc;

use flashbias::coordinator::metrics::Metrics;
use flashbias::decompose::Factors;
use flashbias::factorstore::{
    Cached, FactorService, FactorStore, Fingerprint, RemoteStore,
};
use flashbias::tensor::Tensor;
use flashbias::util::sync::{
    audit_enabled, blocking_violations, check_blocking, find_order_cycle,
    order_edges, reset_audit, Mutex,
};
use flashbias::util::Xoshiro256;

// The process-wide audit state means these tests must not interleave.
static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn small_factors(seed: u64) -> Cached {
    let mut rng = Xoshiro256::new(seed);
    Cached::Factors(Arc::new(Factors::from_tensors(
        Tensor::randn(&[16, 2], 1.0, &mut rng),
        Tensor::randn(&[16, 2], 1.0, &mut rng),
        0.1,
        2,
    )))
}

/// Every tier of the store plus metrics traffic, concurrently: resident
/// hits, evictions into the spill file, spill reloads, remote fetches
/// from a peer service, checkpoint saves, and metrics snapshots that
/// take the one sanctioned cross-module edge
/// (`metrics.store` → `factorstore.inner`).
#[test]
fn serving_traffic_keeps_lock_order_acyclic_and_nonblocking() {
    if !audit_enabled() {
        eprintln!("sync audit compiled out; skipping");
        return;
    }
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    reset_audit();

    let pid = std::process::id();
    let spill = std::env::temp_dir().join(format!("fb_audit_spill_{pid}.jsonl"));
    let save_path = std::env::temp_dir().join(format!("fb_audit_save_{pid}.json"));

    // Leader holds keys 100..108; the follower finds them only via the
    // remote tier.
    let leader = Arc::new(FactorStore::unbounded());
    for k in 100u64..108 {
        leader.insert(Fingerprint(k), small_factors(k));
    }
    let service = FactorService::serve(leader, "127.0.0.1:0").expect("serve");

    // Tight budget (~2 entries of rank-2/n=16 factors) so concurrent
    // inserts constantly evict into the spill file and reload from it.
    let store = Arc::new(
        FactorStore::new(2 * 16 * 2 * 4 * 2 + 64)
            .spill_to(&spill)
            .expect("spill tier")
            .with_remote(RemoteStore::new(service.addr().to_string())),
    );
    let metrics = Arc::new(Metrics::new());
    metrics.attach_store(store.clone());

    let mut handles = Vec::new();
    for t in 0..6u64 {
        let store = store.clone();
        let metrics = metrics.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..60u64 {
                let k = (t * 60 + i) % 12; // churn a small key space
                metrics.on_submit();
                let v = store.get_or_insert_with(Fingerprint(k), || {
                    small_factors(k)
                });
                assert!(v.factors().is_some());
                if i % 3 == 0 {
                    // remote-tier traffic: keys only the leader has (a
                    // transient fetch failure degrades to the local
                    // closure; the remote_hits assertion below still
                    // proves the tier was exercised)
                    let rk = 100 + (i % 8);
                    store.get_or_insert_with(Fingerprint(rk), || {
                        small_factors(rk)
                    });
                }
                if i % 5 == 0 {
                    let _ = store.get(Fingerprint(k));
                    let _ = store.peek(Fingerprint((k + 1) % 12));
                }
                metrics.on_batch(1);
                metrics.on_complete(
                    std::time::Duration::from_micros(5),
                    std::time::Duration::from_micros(7),
                    true,
                );
                if i % 10 == 0 {
                    // snapshot paths: metrics.store held across the
                    // store's counter reads
                    let _ = metrics.store_stats();
                    let _ = metrics.summary();
                    let _ = store.stats();
                }
            }
        }));
    }
    // Checkpoint writer: save() walks every tier (including spill
    // reads) while the workers churn.
    {
        let store = store.clone();
        let save_path = save_path.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..5 {
                store.save(&save_path).expect("checkpoint save");
                std::thread::yield_now();
            }
        }));
    }
    for h in handles {
        h.join().expect("worker thread");
    }
    service.shutdown();

    // The traffic above must actually have exercised every tier —
    // otherwise the audit assertions below prove nothing.
    assert!(store.evictions() > 0, "budget never forced an eviction");
    assert!(store.spill_hits() > 0, "spill tier never reloaded");
    assert!(store.remote_hits() > 0, "remote tier never hit");

    let edges = order_edges();
    assert!(
        find_order_cycle().is_none(),
        "lock-order cycle observed: {:?}\nedges: {edges:?}",
        find_order_cycle()
    );
    assert!(
        blocking_violations().is_empty(),
        "locks held across blocking regions: {:?}",
        blocking_violations()
    );
    // Exactly one cross-lock nesting is sanctioned in this traffic:
    // Metrics::store_stats reading the store's counters.
    let allowed = ("metrics.store".to_string(), "factorstore.inner".to_string());
    assert!(
        edges.iter().all(|e| *e == allowed),
        "unexpected lock-order edge(s): {edges:?}"
    );
    assert!(
        edges.contains(&allowed),
        "audit recorded no edges — did the snapshot path run?"
    );

    let _ = std::fs::remove_file(&spill);
    let _ = std::fs::remove_file(&save_path);
    reset_audit();
}

/// Positive control: the audit must *detect* an inversion and a
/// blocking violation when one is staged deliberately — otherwise the
/// green assertions above would also pass with a broken audit.
#[test]
fn audit_detects_staged_inversion_and_blocking() {
    if !audit_enabled() {
        eprintln!("sync audit compiled out; skipping");
        return;
    }
    let _gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    reset_audit();

    let a = Mutex::new("audit_test.a", 0u32);
    let b = Mutex::new("audit_test.b", 0u32);

    // a → b, then b → a: classic inversion (sequential, so no deadlock).
    {
        let _ga = a.lock_recover();
        let _gb = b.lock_recover();
    }
    {
        let _gb = b.lock_recover();
        let _ga = a.lock_recover();
    }
    let cycle = find_order_cycle().expect("inversion must be detected");
    assert!(cycle.iter().any(|n| n == "audit_test.a"), "{cycle:?}");
    assert!(cycle.iter().any(|n| n == "audit_test.b"), "{cycle:?}");

    // Holding a lock across a blocking region it does not own...
    {
        let _ga = a.lock_recover();
        check_blocking("audit_test::io", &[]);
    }
    let v = blocking_violations();
    assert!(
        v.iter().any(|s| s.contains("audit_test.a") && s.contains("audit_test::io")),
        "staged blocking violation not recorded: {v:?}"
    );
    // ...but an allowlisted holder is fine.
    reset_audit();
    {
        let _ga = a.lock_recover();
        check_blocking("audit_test::io", &["audit_test.a"]);
    }
    assert!(blocking_violations().is_empty());
    reset_audit();
}
