//! Randomized range-finder SVD vs the Jacobi reference oracle: across
//! random shapes and target ranks, the sketch's reconstruction error
//! must sit within the Eckart–Young optimum plus a small tolerance —
//! and it can never beat the optimum.

use flashbias::linalg::{
    eckart_young_error, randomized_svd_factors, reconstruction_error,
    svd_factors,
};
use flashbias::proplite::{forall, gen_dim, Config};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

#[derive(Clone, Debug)]
struct Case {
    n: usize,
    m: usize,
    /// intrinsic rank of the synthetic table
    r0: usize,
    /// target truncation rank
    rank: usize,
    noise: f32,
    seed: u64,
}

fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    for (n, m) in [(c.n / 2, c.m), (c.n, c.m / 2)] {
        if n >= 12 && m >= 12 {
            out.push(Case { n, m, ..c.clone() });
        }
    }
    if c.rank > 1 {
        out.push(Case { rank: c.rank / 2, ..c.clone() });
    }
    out
}

/// Low-rank-plus-noise table: the spectral shape of learned biases
/// (Figure 8) at test-friendly sizes.
fn synthetic_table(c: &Case) -> Tensor {
    let mut rng = Xoshiro256::new(c.seed);
    let a = Tensor::randn(&[c.n, c.r0], 1.0, &mut rng);
    let b = Tensor::randn(&[c.m, c.r0], 1.0, &mut rng);
    a.matmul_t(&b)
        .add(&Tensor::randn(&[c.n, c.m], c.noise, &mut rng))
}

fn randomized_within_eckart_young(case: &Case) -> bool {
    let table = synthetic_table(case);
    let mut rng = Xoshiro256::new(case.seed ^ 0xABCD);
    let (pq, pk) =
        randomized_svd_factors(&table, case.rank, 8, 2, &mut rng);
    let err = reconstruction_error(&table, &pq, &pk) as f64;
    let optimum = eckart_young_error(&table, case.rank);
    // can't beat the optimum (up to f32/f64 spectrum jitter, ~5e-3 per
    // the eckart_young_matches_actual_truncation unit test), and must
    // come close to it
    err + 0.01 >= optimum && err <= optimum + 0.05
}

#[test]
fn prop_randomized_svd_tracks_eckart_young_bound() {
    forall(
        Config::default().cases(15).seed(0xA11CE),
        |rng| Case {
            n: gen_dim(rng, 16, 72),
            m: gen_dim(rng, 16, 72),
            r0: gen_dim(rng, 2, 6),
            rank: gen_dim(rng, 1, 8),
            noise: 0.01,
            seed: rng.next_u64(),
        },
        shrink_case,
        randomized_within_eckart_young,
    );
}

#[test]
fn prop_randomized_matches_jacobi_at_intrinsic_rank() {
    // truncating AT the intrinsic rank: both factorizations recover the
    // table up to the injected noise floor
    forall(
        Config::default().cases(10).seed(0xB0B),
        |rng| Case {
            n: gen_dim(rng, 20, 64),
            m: gen_dim(rng, 20, 64),
            r0: gen_dim(rng, 2, 5),
            rank: 0, // overwritten below
            noise: 0.0,
            seed: rng.next_u64(),
        },
        |_| Vec::new(),
        |case| {
            let case = Case { rank: case.r0, ..case.clone() };
            let table = synthetic_table(&case);
            let mut rng = Xoshiro256::new(case.seed ^ 0x5EED);
            let (pq, pk) =
                randomized_svd_factors(&table, case.rank, 8, 2,
                                       &mut rng);
            let (jq, jk) = svd_factors(&table, case.rank);
            let rand_err = reconstruction_error(&table, &pq, &pk);
            let jacobi_err = reconstruction_error(&table, &jq, &jk);
            rand_err < 1e-3 && jacobi_err < 1e-3
        },
    );
}

#[test]
fn randomized_factor_shapes_match_contract() {
    let mut rng = Xoshiro256::new(4);
    let a = Tensor::randn(&[40, 28], 1.0, &mut rng);
    let (pq, pk) = randomized_svd_factors(&a, 5, 8, 1, &mut rng);
    assert_eq!(pq.shape(), &[40, 5]);
    assert_eq!(pk.shape(), &[28, 5]);
}
