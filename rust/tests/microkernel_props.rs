//! Property tests pinning the microkernel layer to its bit-identity
//! contract (see `rust/src/kernels/microkernel.rs`).
//!
//! Every kernel is compared bit-for-bit against a *portable lane-model
//! reference* written here in plain scalar Rust: lane `l` of a
//! [`LANES`]-wide register file accumulates `a[i·LANES+l] ·
//! b[i·LANES+l]` with `f32::mul_add`, the tail accumulates into one
//! scalar chain, and the file collapses through the shared
//! [`microkernel::reduce`] tree. The scalar fallback and the
//! `--features simd` build both implement exactly this model, so
//! running this suite under either configuration proves the build
//! agrees with the contract — and therefore that the two builds agree
//! with each other.
//!
//! Shape edges covered: empty operands, single-lane tails, exact lane
//! multiples, rank 1, rank larger than a lane block, tile widths off
//! the [`NR`] register-tile grid, and empty tiles.

use flashbias::kernels::microkernel::{
    self, add_assign, axpy, dot, dot4, reduce, row_accum, row_max,
    row_scores, scale_in_place, LANES, NR,
};
use flashbias::proplite::{forall, Config};
use flashbias::tensor::{
    f32_to_bf16, f32_to_f16, Strip, StripDType, Tensor, View2,
};
use flashbias::util::Xoshiro256;

/// The portable lane model: the reference all builds must match
/// bit-for-bit.
fn ref_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f32; LANES];
    let blocks = n / LANES;
    for i in 0..blocks {
        for l in 0..LANES {
            let o = i * LANES + l;
            acc[l] = a[o].mul_add(b[o], acc[l]);
        }
    }
    let mut tail = 0.0f32;
    for i in blocks * LANES..n {
        tail = a[i].mul_add(b[i], tail);
    }
    reduce(acc) + tail
}

fn randv(rng: &mut Xoshiro256, n: usize, scale: f32) -> Vec<f32> {
    Tensor::randn(&[n.max(1)], scale, rng).into_data()[..n].to_vec()
}

/// Lengths that straddle every lane/tail boundary.
fn edge_lengths() -> Vec<usize> {
    vec![
        0,
        1,
        2,
        3,
        NR,
        LANES - 1,
        LANES,
        LANES + 1,
        2 * LANES,
        2 * LANES + 3,
        67,
        128,
    ]
}

#[test]
fn dot_matches_the_lane_model_bitwise() {
    let mut rng = Xoshiro256::new(0xD07);
    for n in edge_lengths() {
        for scale in [1.0f32, 1e-4, 1e4] {
            let a = randv(&mut rng, n, scale);
            let b = randv(&mut rng, n, scale);
            let got = dot(&a, &b);
            let want = ref_dot(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(),
                       "n={n} scale={scale}: {got} vs {want}");
        }
    }
    // mismatched lengths clamp to the shorter operand
    let a = randv(&mut rng, 20, 1.0);
    let b = randv(&mut rng, 13, 1.0);
    assert_eq!(dot(&a, &b).to_bits(), ref_dot(&a[..13], &b).to_bits());
    assert_eq!(dot(&[], &b), 0.0);
}

#[test]
fn dot4_outputs_are_bitwise_equal_to_four_dots() {
    let mut rng = Xoshiro256::new(0xD04);
    for n in edge_lengths() {
        let a = randv(&mut rng, n, 1.0);
        let bs: Vec<Vec<f32>> =
            (0..NR).map(|_| randv(&mut rng, n, 1.0)).collect();
        let d = dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
        for r in 0..NR {
            assert_eq!(d[r].to_bits(), dot(&a, &bs[r]).to_bits(),
                       "n={n} r={r}");
        }
    }
}

#[test]
fn dot4_property_sweep_random_shapes() {
    forall(
        Config::default().cases(300).seed(0x5EED),
        |rng| {
            let n = rng.next_below(40) as usize;
            let a = randv(rng, n, 0.7);
            let bs: Vec<Vec<f32>> =
                (0..NR).map(|_| randv(rng, n, 0.7)).collect();
            (a, bs)
        },
        |_| Vec::new(),
        |(a, bs)| {
            let d = dot4(a, &bs[0], &bs[1], &bs[2], &bs[3]);
            (0..NR).all(|r| {
                d[r].to_bits() == ref_dot(a, &bs[r]).to_bits()
            })
        },
    );
}

#[test]
fn row_scores_matches_scaled_lane_model() {
    let mut rng = Xoshiro256::new(0x5C0);
    // ranks straddle the lane width; widths straddle the NR tile
    for r in [0usize, 1, 3, LANES, LANES + 1, 19] {
        for bk in [0usize, 1, NR - 1, NR, NR + 1, 2 * NR + 3] {
            let rows_n = bk + 5; // j0 offset exercises the row indexing
            let a = randv(&mut rng, r, 1.0);
            let data = randv(&mut rng, rows_n * r.max(1), 1.0);
            let rows = View2::new(rows_n, r, &data[..rows_n * r]);
            let scale = 0.37f32;
            let mut out = vec![f32::NAN; bk]; // overwrite semantics
            row_scores(&a, rows, 5, scale, &mut out);
            for (j, &got) in out.iter().enumerate() {
                let want = ref_dot(&a, rows.row(5 + j)) * scale;
                assert_eq!(got.to_bits(), want.to_bits(),
                           "r={r} bk={bk} j={j}");
            }
        }
    }
}

#[test]
fn row_accum_accumulates_on_the_lane_model() {
    let mut rng = Xoshiro256::new(0xACC);
    for r in [1usize, LANES, 19] {
        for bk in [1usize, NR, 2 * NR + 1] {
            let a = randv(&mut rng, r, 1.0);
            let data = randv(&mut rng, bk * r, 1.0);
            let rows = View2::new(bk, r, &data);
            let pre = randv(&mut rng, bk, 1.0);
            let mut out = pre.clone();
            row_accum(&a, rows, 0, &mut out);
            for j in 0..bk {
                let want = pre[j] + ref_dot(&a, rows.row(j));
                assert_eq!(out[j].to_bits(), want.to_bits(),
                           "r={r} bk={bk} j={j}");
            }
        }
    }
}

#[test]
fn elementwise_kernels_match_scalar_chains_bitwise() {
    let mut rng = Xoshiro256::new(0xE1E);
    for n in edge_lengths() {
        let x = randv(&mut rng, n, 1.0);
        let base = randv(&mut rng, n, 1.0);
        let a = 0.731f32;

        let mut y = base.clone();
        axpy(a, &x, &mut y);
        for i in 0..n {
            let want = a.mul_add(x[i], base[i]);
            assert_eq!(y[i].to_bits(), want.to_bits(), "axpy n={n} i={i}");
        }

        let mut y = base.clone();
        scale_in_place(a, &mut y);
        for i in 0..n {
            assert_eq!(y[i].to_bits(), (base[i] * a).to_bits(),
                       "scale n={n} i={i}");
        }

        let mut y = base.clone();
        add_assign(&x, &mut y);
        for i in 0..n {
            assert_eq!(y[i].to_bits(), (base[i] + x[i]).to_bits(),
                       "add n={n} i={i}");
        }
    }
    // empty everything is a no-op, not a panic
    axpy(2.0, &[], &mut []);
    scale_in_place(2.0, &mut []);
    add_assign(&[], &mut []);
    assert_eq!(row_max(&[]), f32::NEG_INFINITY);
    assert_eq!(row_max(&[3.0, -1.0, 7.5, 2.0]), 7.5);
}

#[test]
fn empty_tiles_produce_no_output_and_no_panic() {
    let a: Vec<f32> = Vec::new();
    let rows = View2::new(0, 0, &[]);
    let mut out: Vec<f32> = Vec::new();
    row_scores(&a, rows, 0, 1.0, &mut out);
    row_accum(&a, rows, 0, &mut out);
    assert!(out.is_empty());
    // rank-0 strips: every dot is the empty sum
    let rows0 = View2::new(4, 0, &[]);
    let mut out0 = vec![1.0f32; 4];
    row_scores(&[], rows0, 0, 2.0, &mut out0);
    assert_eq!(out0, vec![0.0; 4], "rank 0 scores are exactly zero");
}

#[test]
fn microkernel_constants_are_the_documented_tile() {
    // the register tile the speedup numbers in README were measured at
    assert_eq!(LANES, 8);
    assert_eq!(NR, 4);
    assert_eq!(microkernel::reduce([1.0; LANES]), 8.0);
}

// ---------------------------------------------------------------------------
// Quantization round-trip properties (the reduced-precision strips the
// factored tile dequantizes through these kernels)
// ---------------------------------------------------------------------------

#[test]
fn quantization_is_idempotent_per_dtype() {
    // decode → re-quantize must be exact: the representable set is
    // closed under round-trip for every dtype
    forall(
        Config::default().cases(100).seed(0x1DE),
        |rng| {
            let rows = 1 + rng.next_below(12) as usize;
            let cols = 1 + rng.next_below(6) as usize;
            Tensor::randn(&[rows, cols], 1.5, rng)
        },
        |_| Vec::new(),
        |t| {
            [StripDType::Bf16, StripDType::F16].iter().all(|&d| {
                let s = Strip::quantize(t, d);
                let again = Strip::quantize(&s.to_tensor(), d);
                s == again
            })
        },
    );
}

#[test]
fn bf16_and_f16_relative_error_is_half_ulp_bounded() {
    forall(
        Config::default().cases(500).seed(0xB16),
        |rng| Tensor::randn(&[1], 3.0, rng).into_data()[0],
        |_| Vec::new(),
        |&x| {
            let b = Strip::quantize(&Tensor::new(&[1, 1], vec![x]),
                                    StripDType::Bf16)
                .to_tensor()
                .into_data()[0];
            let h = Strip::quantize(&Tensor::new(&[1, 1], vec![x]),
                                    StripDType::F16)
                .to_tensor()
                .into_data()[0];
            // bf16: 8 significand bits → half-ulp 2⁻⁹; f16: 11 bits →
            // half-ulp 2⁻¹² (plus an absolute floor for subnormals)
            (b - x).abs() <= x.abs() / 512.0 + 1e-38
                && (h - x).abs() <= x.abs() / 4096.0 + 6e-8
        },
    );
}

#[test]
fn scalar_encoders_agree_with_strip_quantization() {
    // the pub scalar conversions (used by persistence) and the bulk
    // Strip path must be the same function
    let mut rng = Xoshiro256::new(0xE2C);
    let t = Tensor::randn(&[9, 4], 2.0, &mut rng);
    let bf = Strip::quantize(&t, StripDType::Bf16);
    let hf = Strip::quantize(&t, StripDType::F16);
    let bf_bits = bf.bits_u16().unwrap();
    let hf_bits = hf.bits_u16().unwrap();
    for (i, &x) in t.data().iter().enumerate() {
        assert_eq!(bf_bits[i], f32_to_bf16(x));
        assert_eq!(hf_bits[i], f32_to_f16(x));
    }
}

#[test]
fn i8_error_is_bounded_by_half_a_scale_step() {
    let mut rng = Xoshiro256::new(0x108);
    let t = Tensor::randn(&[24, 5], 1.0, &mut rng);
    let s = Strip::quantize(&t, StripDType::I8);
    let back = s.to_tensor();
    // per-column symmetric scale: |x − decode(x)| ≤ scale/2, where
    // scale = max|col| / 127
    let (_, scales) = s.i8_parts().unwrap();
    for r in 0..24 {
        for c in 0..5 {
            let err = (t.at2(r, c) - back.at2(r, c)).abs();
            assert!(err <= scales[c] * 0.5 + 1e-7,
                    "r={r} c={c}: err {err} vs scale {}", scales[c]);
        }
    }
}
