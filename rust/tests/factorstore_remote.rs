//! Sharing-tier integration (tier-1, artifact-free): a loopback
//! `FactorService` smoke test, the `RemoteStore` round trip, and the
//! ISSUE 5 acceptance criterion — a second coordinator pointed at a
//! peer's factor service plans a Swin bias with `misses=0` SVD work.

use std::sync::Arc;

use flashbias::bias::swin_relative_bias;
use flashbias::coordinator::{Coordinator, CoordinatorConfig};
use flashbias::factorstore::{
    Cached, FactorService, FactorStore, Fingerprint, RemoteStore,
};
use flashbias::iomodel::Geometry;
use flashbias::plan::{BiasSpec, ExecMode, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

const SRAM: usize = 100 * 1024 / 2;

fn lowrank_spec(n: usize, r: usize, seed: u64) -> BiasSpec {
    let mut rng = Xoshiro256::new(seed);
    let a = Tensor::randn(&[n, r], 1.0, &mut rng);
    let b = Tensor::randn(&[n, r], 1.0, &mut rng);
    BiasSpec::static_learned(
        a.matmul_t(&b).add(&Tensor::randn(&[n, n], 1e-4, &mut rng)),
    )
}

#[test]
fn loopback_service_smoke() {
    // artifact-free loopback round trip: known key found, unknown miss
    let leader_store = Arc::new(FactorStore::unbounded());
    let store = leader_store.clone();
    let mut rng = Xoshiro256::new(2);
    let original = Arc::new(flashbias::decompose::Factors::from_tensors(
        Tensor::randn(&[12, 3], 1.0, &mut rng),
        Tensor::randn(&[12, 3], 1.0, &mut rng),
        0.25,
        3,
    ));
    store.insert(Fingerprint(0xBEEF), Cached::Factors(original.clone()));
    let service =
        FactorService::serve(store, "127.0.0.1:0").expect("serve");
    let client = RemoteStore::new(service.addr().to_string());

    let fetched = client
        .try_fetch(Fingerprint(0xBEEF))
        .expect("transport ok")
        .expect("entry found");
    let f = fetched.factors().expect("factors entry");
    assert_eq!(f.rank, 3);
    assert_eq!(f.phi_q, original.phi_q,
               "factors must round-trip the wire exactly");
    assert_eq!(f.phi_k, original.phi_k);
    assert_eq!(f.rel_err, original.rel_err);

    assert!(client
        .try_fetch(Fingerprint(0xDEAD))
        .expect("transport ok")
        .is_none());
    assert_eq!(service.served(), 1);
    // peer traffic must not pollute the leader's own counters: a
    // follower probing for unknown content would otherwise mark a
    // fully warm store dirty (and pose as local SVD work)
    let stats = leader_store.stats();
    assert_eq!((stats.hits, stats.misses), (0, 0),
               "service lookups are uncounted peeks");
    service.shutdown();
}

#[test]
fn rejected_verdicts_share_over_the_wire_too() {
    // a remembered dense-fallback verdict is as valuable as factors:
    // the peer skips the whole spectrum scan
    let store = Arc::new(FactorStore::unbounded());
    store.insert(Fingerprint(7), Cached::Rejected { measured_rank: 99 });
    let service =
        FactorService::serve(store, "127.0.0.1:0").expect("serve");
    let client = RemoteStore::new(service.addr().to_string());
    match client.try_fetch(Fingerprint(7)).expect("transport ok") {
        Some(Cached::Rejected { measured_rank }) => {
            assert_eq!(measured_rank, 99)
        }
        other => panic!("expected rejected verdict, got {other:?}"),
    }
    service.shutdown();
}

#[test]
fn dead_peer_degrades_to_local_decomposition() {
    // nothing listens here: the fetch fails fast and the store falls
    // back to running the SVD itself
    let store = FactorStore::unbounded()
        .with_remote(RemoteStore::new("127.0.0.1:9"));
    let n = 32;
    let spec = lowrank_spec(n, 3, 5);
    let geo = Geometry { n, m: n, c: 32, r: 0, sram: SRAM };
    let plan = Planner::default()
        .plan_with_store(&spec, &geo, &PlanOptions::default(), &store)
        .expect("plan");
    assert!(matches!(plan.mode, ExecMode::Factored { .. }));
    assert_eq!(store.misses(), 1, "decomposed locally");
    assert_eq!(store.remote_hits(), 0);
}

#[test]
fn two_stores_share_one_factor_service() {
    let n = 40;
    let spec = lowrank_spec(n, 4, 17);
    let geo = Geometry { n, m: n, c: 32, r: 0, sram: SRAM };
    let opts = PlanOptions::default();
    let planner = Planner::default();

    let leader = Arc::new(FactorStore::unbounded());
    let cold = planner
        .plan_with_store(&spec, &geo, &opts, &leader)
        .expect("leader plan");
    assert_eq!(leader.misses(), 1);
    let service =
        FactorService::serve(leader.clone(), "127.0.0.1:0")
            .expect("serve");

    let follower = FactorStore::unbounded()
        .with_remote(RemoteStore::new(service.addr().to_string()));
    let warm = planner
        .plan_with_store(&spec, &geo, &opts, &follower)
        .expect("follower plan");
    assert_eq!(follower.misses(), 0, "the follower does no SVD work");
    assert_eq!(follower.remote_hits(), 1);
    match (&cold.mode, &warm.mode) {
        (
            ExecMode::Factored { factors: f0 },
            ExecMode::Factored { factors: f1 },
        ) => {
            assert_eq!(f0.rank, f1.rank);
            assert_eq!(f0.phi_q, f1.phi_q,
                       "shared strips must be bit-identical");
            assert_eq!(f0.phi_k, f1.phi_k);
        }
        other => panic!("expected factored plans, got {other:?}"),
    }
    // fetched once, cached locally: the next plan is a resident hit
    planner
        .plan_with_store(&spec, &geo, &opts, &follower)
        .expect("second follower plan");
    assert_eq!(follower.remote_hits(), 1, "no second network trip");
    assert_eq!(follower.hits(), 1);
    service.shutdown();
}

/// ISSUE 5 acceptance: a second *coordinator* pointed at a peer's
/// `FactorService` plans a Swin bias with zero SVD work.
#[test]
fn second_coordinator_warms_from_the_fleet() {
    let table = swin_relative_bias((12, 12), 1, 0, 6, 0.02).remove(0);
    let spec = BiasSpec::static_learned(table);
    let geo = Geometry::square(144, 64, 0, SRAM);
    // the paper pins R = 16 for Swin; also keeps the test fast
    let opts = PlanOptions {
        rank_override: Some(16),
        ..PlanOptions::default()
    };
    let planner = Planner::default();

    let leader = Coordinator::with_store(
        Arc::new(Runtime::empty()),
        CoordinatorConfig::default(),
        Arc::new(FactorStore::unbounded()),
    );
    leader
        .plan_and_register("swin_host", &planner, &spec, &geo, &opts)
        .expect("leader pays the SVD once");
    assert_eq!(leader.store().misses(), 1);
    let service = leader.serve_store("127.0.0.1:0").expect("serve");

    let follower_store = Arc::new(
        FactorStore::unbounded()
            .with_remote(RemoteStore::new(service.addr().to_string())),
    );
    let follower = Coordinator::with_store(
        Arc::new(Runtime::empty()),
        CoordinatorConfig::default(),
        follower_store.clone(),
    );
    let plan = follower
        .plan_and_register("swin_host", &planner, &spec, &geo, &opts)
        .expect("follower plans through the fleet");
    assert_eq!(follower_store.misses(), 0,
               "misses=0: the follower performed no SVD work");
    assert_eq!(follower_store.remote_hits(), 1);
    assert_eq!(plan.rank(), 16);
    // the tier counters surface in the serving metrics
    assert!(follower
        .metrics()
        .summary()
        .contains("remote_hits=1"));
    service.shutdown();
    follower.shutdown();
    leader.shutdown();
}
