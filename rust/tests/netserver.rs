//! Network serving front-end, end to end over real loopback TCP.
//!
//! * Protocol validation: every malformed request — unknown op, bad
//!   shapes, foreign sessions, oversized/truncated/garbage frames —
//!   comes back as a typed error frame (or a clean close for frame
//!   damage) and never crashes the server.
//! * Admission control: a full admission queue and the session cap
//!   refuse with `overloaded` frames, and the refusals surface in the
//!   server's `stats` counters.
//! * Bitwise fidelity: a session driven over the wire (seed-form
//!   `open` → `prefill` → `step`s → `close`) produces outputs bitwise
//!   equal to an in-process [`SessionState`] replay of the same plan
//!   and payloads — the network layer adds framing, not arithmetic.

use std::io::Write as IoWrite;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use flashbias::coordinator::Coordinator;
use flashbias::jsonlite::Json;
use flashbias::plan::{self, AttentionPlan, SessionState};
use flashbias::runtime::Runtime;
use flashbias::server::{
    demo_plan_name, fetch_stats, register_demo_plan, run_wave,
    synthetic_qkv, synthetic_rows, wait_ready, NetServer, ServeConfig,
    WaveConfig,
};
use flashbias::util::frame::{read_frame, set_io_timeouts, write_frame};

const PLAN_N: usize = 32;
const C: usize = 64; // the demo plan's head width

fn demo_server(cfg: ServeConfig) -> (NetServer, String, AttentionPlan) {
    let coord = Coordinator::new(
        Arc::new(Runtime::empty()),
        cfg.coordinator_config(),
    );
    let plan = register_demo_plan(&coord, PLAN_N).expect("demo plan");
    let srv =
        NetServer::serve(coord, cfg, "127.0.0.1:0").expect("serve");
    let addr = srv.addr().to_string();
    assert!(wait_ready(&addr, Duration::from_secs(10)), "server up");
    (srv, addr, plan)
}

fn connect(addr: &str) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect");
    set_io_timeouts(&s, Duration::from_secs(30)).expect("timeouts");
    s
}

/// One frame out, one frame back.
fn rpc(stream: &mut TcpStream, req: Json) -> Json {
    write_frame(stream, &req).expect("write frame");
    read_frame(stream).expect("read frame").expect("response frame")
}

fn kind_of(resp: &Json) -> Option<&str> {
    assert_eq!(resp.get("ok").as_bool(), Some(false),
               "expected an error frame, got {}", resp.dump());
    resp.get("kind").as_str()
}

fn out_bits(resp: &Json) -> Vec<u32> {
    assert_eq!(resp.get("ok").as_bool(), Some(true),
               "expected ok, got {}", resp.dump());
    resp.get("out")
        .as_arr()
        .expect("out array")
        .iter()
        .map(|x| (x.as_f64().expect("number") as f32).to_bits())
        .collect()
}

#[test]
fn validation_and_session_errors_are_typed_frames() {
    let (srv, addr, _plan) = demo_server(ServeConfig::default());
    let mut s = connect(&addr);

    // ping / stats fast paths
    let pong = rpc(&mut s, Json::obj(vec![("op", Json::str("ping"))]));
    assert_eq!(pong.get("pong").as_bool(), Some(true));
    let stats =
        rpc(&mut s, Json::obj(vec![("op", Json::str("stats"))]));
    assert_eq!(stats.get("ok").as_bool(), Some(true));
    assert!(stats.get("queue_depth").as_f64().is_some());

    // op-level validation
    let bad = rpc(&mut s, Json::obj(vec![("op", Json::str("put"))]));
    assert_eq!(kind_of(&bad), Some("validation"));
    let none = rpc(&mut s, Json::obj(vec![("x", Json::num(1.0))]));
    assert_eq!(kind_of(&none), Some("validation"));
    let bad_plan = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("open")),
        ("plan", Json::str("nope")),
    ]));
    assert_eq!(kind_of(&bad_plan), Some("validation"));

    // sessions are connection-owned: ids you never opened are
    // `session` errors even if another connection owns them
    let foreign = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("step")),
        ("session", Json::num(0.0)),
        ("seed", Json::num(1.0)),
        ("t", Json::num(0.0)),
    ]));
    assert_eq!(kind_of(&foreign), Some("session"));

    // a real session, then shape-level validation against it
    let opened = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("open")),
        ("plan", Json::str(&demo_plan_name(PLAN_N))),
    ]));
    assert_eq!(opened.get("ok").as_bool(), Some(true));
    let sid = opened.get("session").as_usize().expect("session id");

    // seed-form n beyond the plan's context must be refused *before*
    // any allocation happens server-side
    let huge = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("prefill")),
        ("session", Json::num(sid as f64)),
        ("n", Json::num((PLAN_N + 1) as f64)),
        ("seed", Json::num(1.0)),
    ]));
    assert_eq!(kind_of(&huge), Some("validation"));

    // explicit arrays that are not a multiple of C
    let ragged = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("prefill")),
        ("session", Json::num(sid as f64)),
        ("q", Json::Arr(vec![Json::num(1.0); 3])),
        ("k", Json::Arr(vec![Json::num(1.0); 3])),
        ("v", Json::Arr(vec![Json::num(1.0); 3])),
    ]));
    assert_eq!(kind_of(&ragged), Some("validation"));

    // a step row of the wrong width
    let narrow = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("step")),
        ("session", Json::num(sid as f64)),
        ("q", Json::Arr(vec![Json::num(1.0); C - 1])),
        ("k", Json::Arr(vec![Json::num(1.0); C])),
        ("v", Json::Arr(vec![Json::num(1.0); C])),
    ]));
    assert_eq!(kind_of(&narrow), Some("validation"));

    // fill the whole context, then one step too many: a session-state
    // refusal, caught synchronously and returned as a typed frame
    let full = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("prefill")),
        ("session", Json::num(sid as f64)),
        ("n", Json::num(PLAN_N as f64)),
        ("seed", Json::num(1.0)),
        ("echo", Json::Bool(false)),
    ]));
    assert_eq!(full.get("ok").as_bool(), Some(true), "{}", full.dump());
    let exhausted = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("step")),
        ("session", Json::num(sid as f64)),
        ("seed", Json::num(1.0)),
        ("t", Json::num(PLAN_N as f64)),
    ]));
    assert_eq!(kind_of(&exhausted), Some("session"));

    // close works once, then the id is gone
    let closed = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("close")),
        ("session", Json::num(sid as f64)),
    ]));
    assert_eq!(closed.get("closed").as_usize(), Some(sid));
    let again = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("close")),
        ("session", Json::num(sid as f64)),
    ]));
    assert_eq!(kind_of(&again), Some("session"));

    srv.shutdown();
}

#[test]
fn hostile_frames_get_reported_and_closed() {
    let (srv, addr, _plan) = demo_server(ServeConfig::default());

    // a length prefix beyond the request cap: typed `frame` error,
    // then the server hangs up — it never allocates the claimed size
    let mut s = connect(&addr);
    let huge = (64 * 1024 * 1024u32).to_le_bytes();
    s.write_all(&huge).expect("write prefix");
    let resp = read_frame(&mut s).expect("error frame").expect("frame");
    assert_eq!(kind_of(&resp), Some("frame"));
    assert!(read_frame(&mut s).expect("clean close").is_none(),
            "server must close after frame damage");

    // a well-framed payload that is not JSON
    let mut s = connect(&addr);
    let garbage = b"not json at all";
    s.write_all(&(garbage.len() as u32).to_le_bytes()).expect("len");
    s.write_all(garbage).expect("payload");
    let resp = read_frame(&mut s).expect("error frame").expect("frame");
    assert_eq!(kind_of(&resp), Some("frame"));

    // a truncated frame: the length prefix promises 100 bytes, the
    // peer sends 10 and shuts down its write half
    let mut s = connect(&addr);
    s.write_all(&100u32.to_le_bytes()).expect("len");
    s.write_all(&[b'{'; 10]).expect("partial payload");
    s.shutdown(std::net::Shutdown::Write).expect("half close");
    let resp = read_frame(&mut s).expect("error frame").expect("frame");
    assert_eq!(kind_of(&resp), Some("frame"));

    // the server survived all of that and still answers new peers
    let mut s = connect(&addr);
    let pong = rpc(&mut s, Json::obj(vec![("op", Json::str("ping"))]));
    assert_eq!(pong.get("pong").as_bool(), Some(true));

    srv.shutdown();
}

#[test]
fn admission_control_refuses_when_the_queue_is_full() {
    // one admission slot, and a dispatch thread that dawdles 300 ms
    // per item: request 1 is in the dispatcher's sleep, request 2
    // holds the only queue slot, request 3 must be refused at the door
    let cfg = ServeConfig {
        queue_depth: 1,
        dispatch_delay: Duration::from_millis(300),
        ..ServeConfig::default()
    };
    let (srv, addr, _plan) = demo_server(cfg);
    let oneshot = |seed: f64| {
        Json::obj(vec![
            ("op", Json::str("oneshot")),
            ("artifact", Json::str(&demo_plan_name(PLAN_N))),
            ("n", Json::num(4.0)),
            ("seed", Json::num(seed)),
            ("echo", Json::Bool(false)),
        ])
    };
    let mut s1 = connect(&addr);
    let mut s2 = connect(&addr);
    let mut s3 = connect(&addr);
    write_frame(&mut s1, &oneshot(1.0)).expect("send 1");
    std::thread::sleep(Duration::from_millis(80));
    write_frame(&mut s2, &oneshot(2.0)).expect("send 2");
    std::thread::sleep(Duration::from_millis(80));
    write_frame(&mut s3, &oneshot(3.0)).expect("send 3");

    let r3 = read_frame(&mut s3).expect("read 3").expect("frame 3");
    assert_eq!(kind_of(&r3), Some("overloaded"),
               "third request must be refused at admission");
    let r1 = read_frame(&mut s1).expect("read 1").expect("frame 1");
    assert_eq!(r1.get("ok").as_bool(), Some(true), "{}", r1.dump());
    let r2 = read_frame(&mut s2).expect("read 2").expect("frame 2");
    assert_eq!(r2.get("ok").as_bool(), Some(true), "{}", r2.dump());

    // the refusal is on the books
    let stats = fetch_stats(&addr).expect("stats");
    let rejected = stats
        .get("metrics")
        .get("net")
        .get("rejected")
        .as_f64()
        .expect("net.rejected");
    assert!(rejected >= 1.0, "stats must count the refusal");

    srv.shutdown();
}

#[test]
fn session_cap_refuses_as_overloaded() {
    let cfg = ServeConfig {
        max_sessions: 1,
        ..ServeConfig::default()
    };
    let (srv, addr, _plan) = demo_server(cfg);
    let mut s = connect(&addr);
    let open = Json::obj(vec![
        ("op", Json::str("open")),
        ("plan", Json::str(&demo_plan_name(PLAN_N))),
    ]);
    let first = rpc(&mut s, open.clone());
    assert_eq!(first.get("ok").as_bool(), Some(true));
    let second = rpc(&mut s, open);
    assert_eq!(kind_of(&second), Some("overloaded"));
    srv.shutdown();
}

#[test]
fn wire_session_is_bitwise_equal_to_inline_replay() {
    let (srv, addr, plan) = demo_server(ServeConfig::default());
    let (seed, prefill_n, steps) = (42u64, 8usize, 4usize);

    // over the wire, seed-form payloads, echo on
    let mut s = connect(&addr);
    let opened = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("open")),
        ("plan", Json::str(&demo_plan_name(PLAN_N))),
    ]));
    let sid = opened.get("session").as_usize().expect("session id");
    let pre = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("prefill")),
        ("session", Json::num(sid as f64)),
        ("n", Json::num(prefill_n as f64)),
        ("seed", Json::num(seed as f64)),
    ]));
    assert!(pre.get("queue_s").as_f64().is_some());
    assert!(pre.get("exec_s").as_f64().is_some());
    let wire_prefill = out_bits(&pre);
    let mut wire_steps = Vec::new();
    for t in prefill_n..prefill_n + steps {
        let resp = rpc(&mut s, Json::obj(vec![
            ("op", Json::str("step")),
            ("session", Json::num(sid as f64)),
            ("t", Json::num(t as f64)),
            ("seed", Json::num(seed as f64)),
        ]));
        wire_steps.push(out_bits(&resp));
    }
    rpc(&mut s, Json::obj(vec![
        ("op", Json::str("close")),
        ("session", Json::num(sid as f64)),
    ]));
    srv.shutdown();

    // inline replay: the exact same plan object and payload generators
    let mut sess =
        SessionState::new(Arc::new(plan)).expect("inline session");
    let (q, k, v) = synthetic_qkv(seed, prefill_n, C);
    let inline_prefill = sess.prefill(&q, &k, &v).expect("prefill");
    let inline_bits: Vec<u32> = inline_prefill
        .data()
        .iter()
        .map(|x| x.to_bits())
        .collect();
    assert_eq!(wire_prefill, inline_bits, "prefill bitwise");
    for (i, t) in (prefill_n..prefill_n + steps).enumerate() {
        let (qr, kr, vr) = synthetic_rows(seed, t, C);
        let inline = sess.step(&qr, &kr, &vr).expect("step");
        let inline_bits: Vec<u32> =
            inline.iter().map(|x| x.to_bits()).collect();
        assert_eq!(wire_steps[i], inline_bits, "step t={t} bitwise");
    }
}

#[test]
fn oneshot_roundtrip_echo_and_suppression() {
    let (srv, addr, plan) = demo_server(ServeConfig::default());
    let (seed, n) = (7u64, 6usize);
    let mut s = connect(&addr);

    // echo off: shape comes back, the output array does not
    let quiet = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("oneshot")),
        ("artifact", Json::str(&demo_plan_name(PLAN_N))),
        ("n", Json::num(n as f64)),
        ("seed", Json::num(seed as f64)),
        ("echo", Json::Bool(false)),
    ]));
    assert_eq!(quiet.get("ok").as_bool(), Some(true));
    assert!(quiet.get("out").is_null(), "echo=false must drop `out`");
    let shape: Vec<usize> = quiet
        .get("shape")
        .as_arr()
        .expect("shape")
        .iter()
        .map(|x| x.as_usize().expect("dim"))
        .collect();
    assert_eq!(shape, vec![n, C]);

    // echo on: the payload matches a direct plan execution
    let loud = rpc(&mut s, Json::obj(vec![
        ("op", Json::str("oneshot")),
        ("artifact", Json::str(&demo_plan_name(PLAN_N))),
        ("n", Json::num(n as f64)),
        ("seed", Json::num(seed as f64)),
    ]));
    let bits = out_bits(&loud);
    let (q, k, v) = synthetic_qkv(seed, n, C);
    let reference = plan::execute(&plan, &q, &k, &v).expect("execute");
    for (i, (got, want)) in
        bits.iter().zip(reference.data()).enumerate()
    {
        let got = f32::from_bits(*got);
        assert!((got - want).abs() < 1e-4,
                "oneshot[{i}]: {got} vs {want}");
    }
    srv.shutdown();
}

#[test]
fn loadgen_wave_against_a_live_server_is_clean() {
    let (srv, addr, _plan) = demo_server(ServeConfig::default());
    let out = run_wave(&WaveConfig {
        addr: addr.clone(),
        plan: demo_plan_name(PLAN_N),
        connections: 4,
        requests_per_conn: 2,
        prefill_rows: 6,
        decode_steps: 2,
        seed: 9,
    });
    assert_eq!(out.protocol_errors, 0, "protocol errors");
    assert_eq!(out.errors, 0, "typed errors");
    // 4 conns × 2 interactions × (1 prefill + 2 steps)
    assert_eq!(out.completed, 24);
    assert!(out.latency.len() as u64 == out.completed);
    assert!(out.throughput() > 0.0);

    // the flush policy actually ran: reasons are on the books
    let stats = fetch_stats(&addr).expect("stats");
    let reasons = stats.get("metrics").get("net").get("flush_reasons");
    let total: f64 = ["tokens", "deadline", "ratio", "drain"]
        .into_iter()
        .filter_map(|k| reasons.get(k).as_f64())
        .sum();
    assert!(total >= 1.0, "no flushes recorded: {}", stats.dump());
    srv.shutdown();
}
