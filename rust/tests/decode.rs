//! Prefill/decode split, end to end.
//!
//! * Every decode step at position `t` must match row `t` of a full
//!   prefill recompute over `[0..t]` within 1e-5 — across all four exec
//!   modes (no-bias / dense / factored / JIT), causal and not, and
//!   ragged cross-attention prefixes (`m_p > n_p`).
//! * A fully-masked step's 1×M path must return exact zeros.
//! * The coordinator's multi-session continuous-batched decode loop
//!   must be **bitwise** stable across batcher flush orderings, and
//!   bitwise equal to the inline (no coordinator) session path.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, Response,
    SessionApiError,
};
use flashbias::iomodel::Geometry;
use flashbias::kernels::{self, KernelConfig, NoBias};
use flashbias::plan::{
    self, AttentionPlan, BiasSpec, PlanOptions, Planner, SessionError,
    SessionState,
};
use flashbias::runtime::{HostValue, Runtime};
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

const C: usize = 8;
const SRAM: usize = 100 * 1024 / 2;

fn geo(n: usize, m: usize) -> Geometry {
    Geometry { n, m, c: C, r: 0, sram: SRAM }
}

fn plan_spec(spec: &BiasSpec, n: usize, m: usize, causal: bool,
             prefer_jit: bool) -> AttentionPlan {
    Planner::default()
        .plan(
            spec,
            &geo(n, m),
            &PlanOptions { causal, prefer_jit,
                           ..PlanOptions::default() },
        )
        .expect("plan")
}

// ---------------------------------------------------------------------------
// Exactness: decode ≡ prefill recompute, all modes × causal × ragged
// ---------------------------------------------------------------------------

/// Drive one session to the end of its context: prefill `[0,
/// prefill_to)`, then one step per remaining position, comparing every
/// step against an independently planned full recompute over the
/// prefix. `make_spec(n, m)` must yield the same bias values on
/// `[0, n) × [0, m)` for every truncation.
fn session_matches_recompute(
    make_spec: &dyn Fn(usize, usize) -> BiasSpec,
    causal: bool,
    prefer_jit: bool,
    n: usize,
    prefill_to: usize,
    expect_mode: &str,
    seed: u64,
) {
    let plan = Arc::new(plan_spec(&make_spec(n, n), n, n, causal,
                                  prefer_jit));
    assert_eq!(plan.mode_name(), expect_mode, "wrong exec mode");
    let mut sess = SessionState::new(Arc::clone(&plan)).expect("open");
    let mut rng = Xoshiro256::new(seed);
    let q = Tensor::randn(&[n, C], 1.0, &mut rng);
    let k = Tensor::randn(&[n, C], 1.0, &mut rng);
    let v = Tensor::randn(&[n, C], 1.0, &mut rng);
    if prefill_to > 0 {
        let out = sess
            .prefill(&q.slice_rows(0, prefill_to),
                     &k.slice_rows(0, prefill_to),
                     &v.slice_rows(0, prefill_to))
            .expect("prefill");
        assert_eq!(out.shape(), &[prefill_to, C]);
    }
    for t in prefill_to..n {
        let out = sess
            .step(q.view2().row(t), k.view2().row(t), v.view2().row(t))
            .expect("step");
        let tp = plan_spec(&make_spec(t + 1, t + 1), t + 1, t + 1,
                           causal, prefer_jit);
        let full = plan::execute(
            &tp,
            &q.slice_rows(0, t + 1),
            &k.slice_rows(0, t + 1),
            &v.slice_rows(0, t + 1),
        )
        .expect("recompute");
        for (j, (a, b)) in
            out.iter().zip(full.view2().row(t)).enumerate()
        {
            assert!((a - b).abs() < 1e-5,
                    "{expect_mode} causal={causal} t={t} j={j}: \
                     {a} vs {b}");
        }
    }
    assert_eq!(sess.remaining(), 0);
}

#[test]
fn nobias_decode_matches_recompute() {
    for (causal, seed) in [(false, 10), (true, 11)] {
        session_matches_recompute(&|_, _| BiasSpec::None, causal, false,
                                  19, 5, "no-bias", seed);
    }
}

#[test]
fn factored_decode_matches_recompute() {
    for (causal, seed) in [(false, 12), (true, 13)] {
        session_matches_recompute(&|n, m| BiasSpec::alibi(n, m, 0.25),
                                  causal, false, 19, 5, "factored",
                                  seed);
    }
}

#[test]
fn jit_decode_matches_recompute() {
    for (causal, seed) in [(false, 14), (true, 15)] {
        session_matches_recompute(&|n, m| BiasSpec::alibi(n, m, 0.25),
                                  causal, true, 19, 5, "jit", seed);
    }
}

#[test]
fn dense_decode_matches_recompute() {
    // a full-rank random table defeats every factorization tolerance,
    // forcing the dense-fallback mode (table-row strips per step)
    let table =
        Tensor::randn(&[19, 19], 1.0, &mut Xoshiro256::new(99));
    let make = |n: usize, m: usize| {
        BiasSpec::dense(table.slice_rows(0, n).slice_cols(0, m))
    };
    for (causal, seed) in [(false, 16), (true, 17)] {
        session_matches_recompute(&make, causal, false, 19, 5, "dense",
                                  seed);
    }
}

#[test]
fn ragged_prefix_decode_matches_recompute() {
    // cross-attention-style session: the prompt has more K/V rows than
    // query rows (m0 > n0), so every later step sees a shifted cache
    let (n, n0, m0) = (20usize, 4usize, 9usize);
    let plan = Arc::new(plan_spec(&BiasSpec::alibi(n, n, 0.25), n, n,
                                  true, false));
    let mut sess = SessionState::new(Arc::clone(&plan)).expect("open");
    let mut rng = Xoshiro256::new(77);
    let q = Tensor::randn(&[n, C], 1.0, &mut rng);
    let k = Tensor::randn(&[n, C], 1.0, &mut rng);
    let v = Tensor::randn(&[n, C], 1.0, &mut rng);
    sess.prefill(&q.slice_rows(0, n0), &k.slice_rows(0, m0),
                 &v.slice_rows(0, m0))
        .expect("ragged prefill");
    // the cache runs out at g.m = n rows: n − m0 steps fit
    for s in 0..(n - m0) {
        let t = n0 + s; // query position
        let mt = m0 + s + 1; // cache rows the step attends
        let out = sess
            .step(q.view2().row(t), k.view2().row(t), v.view2().row(t))
            .expect("step");
        let tp = plan_spec(&BiasSpec::alibi(t + 1, mt, 0.25), t + 1, mt,
                           true, false);
        let full = plan::execute(
            &tp,
            &q.slice_rows(0, t + 1),
            &k.slice_rows(0, mt),
            &v.slice_rows(0, mt),
        )
        .expect("recompute");
        for (j, (a, b)) in
            out.iter().zip(full.view2().row(t)).enumerate()
        {
            assert!((a - b).abs() < 1e-5, "ragged t={t} j={j}: {a} vs {b}");
        }
    }
    assert!(matches!(
        sess.step(q.view2().row(0), k.view2().row(0), v.view2().row(0)),
        Err(SessionError::ContextExhausted { .. })
    ));
}

#[test]
fn fully_masked_step_is_exact_zero_on_the_1xm_path() {
    // i = 0 of a logical n = 6 problem with only m = 3 cached keys:
    // limit = 0 + (3 − 6) < 0, every key is future, l must stay 0.0
    let mut rng = Xoshiro256::new(5);
    let q = Tensor::randn(&[1, C], 1.0, &mut rng);
    let k = Tensor::randn(&[3, C], 1.0, &mut rng);
    let v = Tensor::randn(&[3, C], 1.0, &mut rng);
    let cfg = KernelConfig::for_geometry(&geo(6, 3));
    let mut out = vec![1.0f32; C]; // poisoned on purpose
    let carry = kernels::run_decode_step(
        q.view2().row(0), k.view2(), v.view2(), &NoBias, 0, 6, true,
        1.0, &cfg, &mut out,
    );
    assert_eq!(carry.l, 0.0);
    assert!(out.iter().all(|&x| x == 0.0), "masked row must be zero");
}

// ---------------------------------------------------------------------------
// Coordinator: continuous batching, flush-ordering bitwise stability
// ---------------------------------------------------------------------------

const N: usize = 24;
const PREFILLS: [usize; 3] = [4, 6, 9];
const STEPS: usize = 8;

fn serving_plan() -> AttentionPlan {
    plan_spec(&BiasSpec::alibi(N, N, 0.25), N, N, true, false)
}

fn coordinator(max_batch: usize) -> Coordinator {
    Coordinator::new(
        Arc::new(Runtime::empty()),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_depth: 64,
        },
    )
}

/// Deterministic per-session payloads shared by every run.
fn session_data(s: usize) -> (Tensor, Tensor, Tensor) {
    let mut rng = Xoshiro256::new(1000 + s as u64);
    (
        Tensor::randn(&[N, C], 1.0, &mut rng),
        Tensor::randn(&[N, C], 1.0, &mut rng),
        Tensor::randn(&[N, C], 1.0, &mut rng),
    )
}

fn oneshot_data() -> (Tensor, Tensor, Tensor) {
    let mut rng = Xoshiro256::new(2000);
    (
        Tensor::randn(&[N, C], 1.0, &mut rng),
        Tensor::randn(&[N, C], 1.0, &mut rng),
        Tensor::randn(&[N, C], 1.0, &mut rng),
    )
}

#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum Work {
    Prefill(usize),
    Step(usize, usize),
    OneShot,
}

fn drain(coord: &mut Coordinator, want: usize) -> Vec<Response> {
    coord.flush_all().expect("flush");
    let mut out = Vec::new();
    while out.len() < want {
        match coord.recv_timeout(Duration::from_secs(30)) {
            Some(r) => out.push(r),
            None => panic!("lost responses: {}/{want}", out.len()),
        }
    }
    out
}

/// Run the same logical workload — 3 session prefills, 8 decode steps
/// per session, one full-length one-shot — under a given batch size,
/// step interleaving, and flush cadence. Returns every output keyed by
/// its logical work item.
fn run_script(max_batch: usize, round_robin: bool,
              flush_every: Option<usize>) -> HashMap<Work, Vec<f32>> {
    let mut coord = coordinator(max_batch);
    coord.register_plan("ab", serving_plan()).expect("register");
    let mut ids: HashMap<u64, Work> = HashMap::new();
    let mut submitted = 0usize;

    let mut sessions = Vec::new();
    for (s, &p) in PREFILLS.iter().enumerate() {
        let sid = coord.open_session("ab").expect("open");
        let (q, k, v) = session_data(s);
        let rid = coord
            .prefill(sid, q.slice_rows(0, p), k.slice_rows(0, p),
                     v.slice_rows(0, p))
            .expect("prefill");
        ids.insert(rid, Work::Prefill(s));
        submitted += 1;
        sessions.push(sid);
    }

    // the step schedule: round-robin interleaves sessions per position;
    // the alternative runs each session to completion before the next
    let mut schedule = Vec::new();
    if round_robin {
        for t in 0..STEPS {
            for s in 0..sessions.len() {
                schedule.push((s, t));
            }
        }
    } else {
        for s in 0..sessions.len() {
            for t in 0..STEPS {
                schedule.push((s, t));
            }
        }
    }
    // a one-shot rides along mid-stream in one run, at the end in the
    // other — it must land in a mixed batch either way
    let oneshot_at = if round_robin { schedule.len() / 2 }
                     else { schedule.len() };
    for (idx, &(s, t)) in schedule.iter().enumerate() {
        if idx == oneshot_at {
            let (q, k, v) = oneshot_data();
            let rid = coord
                .submit("ab", vec![
                    HostValue::F32(q),
                    HostValue::F32(k),
                    HostValue::F32(v),
                ])
                .expect("one-shot");
            ids.insert(rid, Work::OneShot);
            submitted += 1;
        }
        let (q, k, v) = session_data(s);
        let pos = PREFILLS[s] + t;
        let rid = coord
            .step(sessions[s], q.view2().row(pos), k.view2().row(pos),
                  v.view2().row(pos))
            .expect("step");
        ids.insert(rid, Work::Step(s, t));
        submitted += 1;
        if let Some(every) = flush_every {
            if (idx + 1) % every == 0 {
                coord.flush_all().expect("flush");
            }
        }
    }
    if oneshot_at == schedule.len() {
        let (q, k, v) = oneshot_data();
        let rid = coord
            .submit("ab", vec![
                HostValue::F32(q),
                HostValue::F32(k),
                HostValue::F32(v),
            ])
            .expect("one-shot");
        ids.insert(rid, Work::OneShot);
        submitted += 1;
    }

    let responses = drain(&mut coord, submitted);
    let mut out = HashMap::new();
    for resp in responses {
        let work = ids[&resp.id];
        let t = resp.outputs.expect("response ok");
        let data = t[0].as_f32().expect("f32").data().to_vec();
        out.insert(work, data);
    }
    for (s, &sid) in sessions.iter().enumerate() {
        let handle = coord.session(sid).expect("still open");
        assert_eq!(handle.read().pos(), PREFILLS[s] + STEPS);
        assert!(coord.close_session(sid).is_some());
    }
    assert_eq!(coord.open_sessions(), 0);
    coord.shutdown();
    out
}

#[test]
fn decode_loop_is_bitwise_stable_across_flush_orderings() {
    // same logical workload, three very different batching regimes
    let a = run_script(3, true, Some(5));
    let b = run_script(16, false, None);
    let c = run_script(1, true, None);
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), c.len());
    for (work, va) in &a {
        let vb = &b[work];
        let vc = &c[work];
        let bits = |v: &[f32]| {
            v.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        };
        assert_eq!(bits(va), bits(vb), "{work:?}: A vs B");
        assert_eq!(bits(va), bits(vc), "{work:?}: A vs C");
    }

    // and the coordinator path is bitwise the inline-session path
    let plan = Arc::new(serving_plan());
    for s in 0..PREFILLS.len() {
        let mut sess =
            SessionState::new(Arc::clone(&plan)).expect("open");
        let (q, k, v) = session_data(s);
        let p = PREFILLS[s];
        sess.prefill(&q.slice_rows(0, p), &k.slice_rows(0, p),
                     &v.slice_rows(0, p))
            .expect("prefill");
        for t in 0..STEPS {
            let pos = p + t;
            let inline = sess
                .step(q.view2().row(pos), k.view2().row(pos),
                      v.view2().row(pos))
                .expect("step");
            let served = &a[&Work::Step(s, t)];
            assert_eq!(
                inline.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                served.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "session {s} step {t}: inline vs coordinator"
            );
        }
    }

    // the one-shot that rode along in the mixed batches is correct
    let (q, k, v) = oneshot_data();
    let full = plan::execute(&plan, &q, &k, &v).expect("reference");
    let served = &a[&Work::OneShot];
    for (j, (a, b)) in served.iter().zip(full.data()).enumerate() {
        assert!((a - b).abs() < 1e-4, "one-shot j={j}: {a} vs {b}");
    }
}

#[test]
fn session_api_errors_are_typed() {
    let mut coord = coordinator(4);
    coord.register_plan("ab", serving_plan()).expect("register");
    let mul = plan_spec(&BiasSpec::cos_multiplicative(16, 16), 16, 16,
                        false, false);
    coord.register_plan("mul", mul).expect("register");

    assert!(matches!(coord.open_session("nope"),
                     Err(SessionApiError::UnknownPlan(_))));
    assert!(matches!(
        coord.open_session("mul"),
        Err(SessionApiError::State(
            SessionError::DecodeUnsupported { .. }
        ))
    ));
    let row = [0.0f32; C];
    assert!(matches!(coord.step(404, &row, &row, &row),
                     Err(SessionApiError::UnknownSession(404))));

    let sid = coord.open_session("ab").expect("open");
    let short = [0.0f32; C - 1];
    assert!(matches!(
        coord.step(sid, &short, &row, &row),
        Err(SessionApiError::State(SessionError::ShapeMismatch {
            what: "q row",
            ..
        }))
    ));
    // a failed step must not have touched the cache
    assert_eq!(coord.session(sid).expect("open").read().pos(), 0);

    let (q, k, v) = session_data(0);
    coord
        .prefill(sid, q.slice_rows(0, 4), k.slice_rows(0, 4),
                 v.slice_rows(0, 4))
        .expect("prefill");
    assert!(matches!(
        coord.prefill(sid, q.clone(), k.clone(), v.clone()),
        Err(SessionApiError::State(SessionError::NotFresh { pos: 4 }))
    ));
    let want = drain(&mut coord, 1);
    assert!(want[0].outputs.is_ok());
    coord.shutdown();
}
