//! Quickstart: load the AOT artifacts, run ALiBi attention three ways
//! (dense bias / FlashBias factored / in-kernel JIT), verify they agree,
//! and print timing + the bias-storage saving.
//!
//!     make artifacts && cargo run --release --example quickstart

use flashbias::benchkit::{bench_artifact, bias_input_bytes, Table};
use flashbias::bias::{Alibi, ExactBias};
use flashbias::decompose;
use flashbias::iomodel::{self, Geometry};
use flashbias::runtime::Runtime;
use flashbias::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    println!("platform: {}", rt.platform());
    println!("artifacts: {}", rt.names().len());

    // --- 1. correctness: the three ALiBi encodings agree -----------------
    let run = |name: &str| -> anyhow::Result<flashbias::tensor::Tensor> {
        let out = rt.load(name)?.run(&rt.example_inputs(name)?)?;
        Ok(out[0].as_f32().unwrap().clone())
    };
    let dense = run("causal_alibi_dense_n256")?;
    let fact = run("causal_alibi_factored_n256")?;
    let jit = run("causal_alibi_jit_n256")?;
    println!(
        "\nALiBi encodings agree: dense↔factored rel={:.2e}, \
         dense↔jit rel={:.2e}",
        fact.rel_err(&dense),
        jit.rel_err(&dense)
    );
    assert!(fact.rel_err(&dense) < 1e-3);
    assert!(jit.rel_err(&dense) < 1e-3);

    // --- 2. the decomposition itself (Example 3.4) -----------------------
    let alibi = Alibi::new(256, 256, 0.25);
    let factors = decompose::from_exact(&alibi);
    println!(
        "\nExample 3.4: ALiBi rank = {}, reconstruction err = {:.2e}",
        factors.rank, factors.rel_err
    );
    println!(
        "bias storage: dense {} -> factored {} ({}x smaller)",
        human_bytes(alibi.dense().size_bytes() as u64),
        human_bytes(factors.size_bytes() as u64),
        alibi.dense().size_bytes() / factors.size_bytes()
    );

    // --- 3. measured timing ----------------------------------------------
    let mut table = Table::new("quickstart timing (N=256, H=8, C=64)");
    for name in ["causal_pure_n256", "causal_alibi_dense_n256",
                 "causal_alibi_factored_n256", "causal_alibi_jit_n256"] {
        let mut row = bench_artifact(&rt, name, 2, 10);
        row.note = format!(
            "bias-input bytes: {}",
            human_bytes(bias_input_bytes(&rt, name))
        );
        table.row(row);
    }
    drop(table);

    // --- 4. the theory (Example 3.9) --------------------------------------
    let g = Geometry::square(16384, 64, 64, 100 * 1024 / 2);
    println!(
        "\nExample 3.9 (N=16384, C=R=64, S=100KB fp16): \
         model predicts FlashBias IO {:.1}x smaller than dense-bias",
        iomodel::flash_dense_bias_io(&g) / iomodel::flashbias_io(&g)
    );
    println!("quickstart OK");
    Ok(())
}
