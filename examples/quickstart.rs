//! Quickstart: the whole FlashBias pipeline in three lines —
//! `BiasSpec → Planner → execute` — then the same plan through the
//! tiled simulator and (when artifacts are built) the PJRT runtime.
//!
//!     cargo run --release --example quickstart
//!     # optional PJRT section: make artifacts first

use std::sync::Arc;

use flashbias::iomodel::Geometry;
use flashbias::plan::{
    self, BiasSpec, Executor, PjrtExecutor, PlanOptions, Planner,
    SimExecutor,
};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::{human_bytes, Xoshiro256};

fn main() -> anyhow::Result<()> {
    let (n, c) = (256usize, 64usize);
    let sram = 100 * 1024 / 2; // Example 3.9: 100 KB of fp16
    let mut rng = Xoshiro256::new(0);
    let q = Tensor::randn(&[n, c], 1.0, &mut rng);
    let k = Tensor::randn(&[n, c], 1.0, &mut rng);
    let v = Tensor::randn(&[n, c], 1.0, &mut rng);

    // --- 1. the three-line pipeline --------------------------------------
    let spec = BiasSpec::alibi(n, n, 0.25);
    let plan = Planner::default().plan(
        &spec,
        &Geometry::square(n, c, 0, sram),
        &PlanOptions { causal: true, ..PlanOptions::default() },
    )?;
    let out = plan::execute(&plan, &q, &k, &v)?;
    println!("plan:   {}", plan.summary());
    println!("output: {:?} (host executor)", out.shape());

    // --- 2. the jit mode of the same bias agrees -------------------------
    let jit_plan = Planner::default().plan(
        &spec,
        &Geometry::square(n, c, 0, sram),
        &PlanOptions {
            causal: true,
            prefer_jit: true,
            ..PlanOptions::default()
        },
    )?;
    let jit_out = plan::execute(&jit_plan, &q, &k, &v)?;
    println!(
        "factored ↔ jit agree: rel err {:.2e}",
        jit_out.rel_err(&out)
    );
    assert!(jit_out.rel_err(&out) < 1e-4);

    // --- 3. same plan, simulator backend: numerics + HBM accounting ------
    let sim = SimExecutor::default();
    let sim_out = sim.execute(&plan, &q, &k, &v)?;
    assert!(sim_out.rel_err(&out) < 1e-4);
    let rep = sim.last_report().expect("report");
    println!(
        "simulator: rel err {:.2e}, HBM {} elems (predicted {:.3e}, \
         dense-bias baseline {:.3e} → {:.1}x)",
        sim_out.rel_err(&out),
        rep.hbm_total(),
        plan.predicted_io,
        plan.dense_io,
        plan.io_saving()
    );

    // --- 4. the storage story (Thm 3.2) ----------------------------------
    let dense_bytes = n * n * 4;
    println!(
        "bias storage: dense {} -> plan {} ({}x smaller)",
        human_bytes(dense_bytes as u64),
        human_bytes(plan.bias_storage_bytes.max(1) as u64),
        dense_bytes / plan.bias_storage_bytes.max(1)
    );

    // --- 5. PJRT backend (optional: requires `make artifacts`) -----------
    match Runtime::open_default() {
        Ok(rt) => {
            let rt = Arc::new(rt);
            println!("\nplatform: {}", rt.platform());
            // the "attn" artifact family is non-causal: plan the same
            // bias without the mask for the cross-backend check
            let flat_plan = Planner::default().plan(
                &spec,
                &Geometry::square(n, c, 0, sram),
                &PlanOptions::default(),
            )?;
            let host_out = plan::execute(&flat_plan, &q, &k, &v)?;
            let pjrt = PjrtExecutor::new(rt, "attn");
            match pjrt.execute(&flat_plan, &q, &k, &v) {
                Ok(pout) => {
                    let rel = pout.rel_err(&host_out);
                    println!("pjrt executor: rel err vs host {rel:.2e}");
                    assert!(rel < 1e-3, "pjrt disagrees with host: {rel}");
                }
                Err(e) => println!("pjrt executor skipped: {e}"),
            }
        }
        Err(e) => {
            println!("\nPJRT section skipped ({e})");
        }
    }
    println!("quickstart OK");
    Ok(())
}
