//! End-to-end driver: a GPT-2-shaped causal + ALiBi LM served through the
//! FULL system — router → dynamic batcher → worker pool → PJRT-compiled
//! Pallas kernels — on a realistic mixed-length request stream.
//!
//! The plan API decides what is served: `BiasSpec::None` plans to the
//! `pure` variant (the Δ baseline) and `BiasSpec::alibi` plans to
//! `factored` (FlashBias); the `dense` variant is the baseline the paper
//! compares against, executed for the same bias the planner *refused* to
//! stream densely. The predicted IO gap between those plans is the
//! quantity Table 3 measures as Δ wall-clock.
//!
//!     make artifacts && cargo run --release --example serve_llm

use std::sync::Arc;
use std::time::{Duration, Instant};

use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouteKey, Router,
};
use flashbias::iomodel::Geometry;
use flashbias::plan::{BiasSpec, PjrtExecutor, PlanOptions, Planner};
use flashbias::runtime::{HostValue, Runtime};
use flashbias::util::{human_secs, Xoshiro256};

const REQUESTS: usize = 48;

fn serve_variant(rt: &Arc<Runtime>, variant: &str) -> anyhow::Result<()> {
    let router = Router::from_runtime(rt);
    let key = RouteKey::new("gpt2", variant);
    let max_n = router
        .max_bucket(&key)
        .ok_or_else(|| anyhow::anyhow!("no gpt2/{variant} artifacts"))?;

    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
            workers: 2,
            queue_depth: 64,
        },
    );

    // mixed-length stream: lengths uniform in [1, max_n], routed to the
    // smallest adequate bucket; token payloads drawn per request
    let mut rng = Xoshiro256::new(7);
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut exec_total = Duration::ZERO;
    for _ in 0..REQUESTS {
        let want_n = 1 + rng.next_below(max_n as u64) as usize;
        let (artifact, bucket) = router.route(&key, want_n).unwrap();
        let mut inputs = rt.example_inputs(artifact)?;
        // randomize the token input (the activation); weights reused
        let spec = rt.spec(artifact).unwrap();
        for &idx in &spec.activation_indices() {
            if let HostValue::I32(tokens, shape) = &inputs[idx] {
                let fresh: Vec<i32> = (0..tokens.len())
                    .map(|_| rng.next_below(512) as i32)
                    .collect();
                inputs[idx] = HostValue::I32(fresh, shape.clone());
            }
        }
        let _ = bucket;
        // bounded backpressure retry; responses drained while waiting
        // still count toward completion (and a non-retryable error —
        // unknown artifact, stopped pool — propagates instead of
        // spinning forever)
        flashbias::server::submit_with_retry(
            &mut coord,
            artifact,
            inputs,
            |resp| {
                match &resp.outputs {
                    Ok(_) => exec_total += resp.exec_time,
                    Err(_) => failed += 1,
                }
                completed += 1;
            },
        )?;
        submitted += 1;
    }
    coord.flush_all()?;
    while completed < submitted {
        match coord.recv_timeout(Duration::from_secs(120)) {
            Some(resp) => {
                // same accounting as the drain path above: record the
                // failure, keep draining, report after
                match &resp.outputs {
                    Ok(_) => exec_total += resp.exec_time,
                    Err(_) => failed += 1,
                }
                completed += 1;
            }
            None => anyhow::bail!("serve loop stalled"),
        }
    }
    if failed > 0 {
        anyhow::bail!("{failed} of {submitted} requests failed");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "gpt2/{variant:9} {completed} reqs in {:.2}s = {:5.1} req/s | \
         exec p50 {} p99 {} | queue p50 {} | batches {} (mean size {:.1})",
        wall,
        completed as f64 / wall,
        human_secs(m.exec_stats().p50()),
        human_secs(m.exec_stats().p99()),
        human_secs(m.queue_stats().p50()),
        m.batches(),
        m.mean_batch_size(),
    );
    coord.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // --- what the planner says about the serving bias --------------------
    let planner = Planner::default();
    let geo = Geometry::square(256, 64, 0, 100 * 1024 / 2);
    let copts = PlanOptions {
        causal: true,
        ..PlanOptions::default()
    };
    let pure = planner.plan(&BiasSpec::None, &geo, &copts)?;
    let alibi =
        planner.plan(&BiasSpec::alibi(256, 256, 0.25), &geo, &copts)?;
    println!("serving plans (N=256 bucket):");
    println!("  no-bias: {}", pure.summary());
    println!("  alibi:   {}", alibi.summary());
    println!(
        "  predicted bias-processing IO: dense {:.3e} vs plan {:.3e} \
         ({:.1}x) — the Δ Table 3 measures\n",
        alibi.dense_io,
        alibi.predicted_io,
        alibi.io_saving()
    );

    let rt = Arc::new(Runtime::open_default()?);
    println!(
        "serving GPT-2-shaped causal+ALiBi LM ({} requests/variant, \
         mixed lengths) through router -> batcher -> workers -> PJRT\n",
        REQUESTS
    );
    // variants come from the plans: pure (Δ baseline) and the planner's
    // pick for ALiBi; `dense` is the paper's comparison baseline
    let variants = [
        PjrtExecutor::variant(&pure.mode),
        "dense",
        PjrtExecutor::variant(&alibi.mode),
    ];
    for variant in variants {
        serve_variant(&rt, variant)?;
    }
    println!(
        "\nTable 3 reading: Δ(dense − pure) vs Δ(factored − pure) is the \
         bias-processing overhead the paper reports; see \
         benches/table3_gpt2.rs for the per-iteration measurement."
    );
    Ok(())
}
