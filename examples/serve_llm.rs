//! End-to-end serving driver, two acts:
//!
//! 1. **Streaming sessions** (host kernel engine; no artifacts needed):
//!    three concurrent LM sessions with ragged prefixes served through
//!    the prefill/decode split — `open_session` → `prefill` (one
//!    batched O(N·M) pass that fills the session's KV cache) →
//!    interleaved `step` calls, each an exact 1×M pass over the cache
//!    with the ALiBi bias generated as an O(1)-IO strip. Steps from
//!    *different* sessions, prefills, and one-shot traffic share the
//!    dynamic batcher, so a single worker flush carries a mixed batch
//!    (`Batch::split_by_kind` → one `decode_steps` call). Session API
//!    misuse comes back as typed `SessionApiError`s, never a worker
//!    panic.
//!
//! 2. **One-shot variants over PJRT** (requires `make artifacts`;
//!    skipped gracefully when absent): a GPT-2-shaped causal + ALiBi
//!    LM on a mixed-length request stream, router → batcher → workers
//!    → PJRT-compiled Pallas kernels. `BiasSpec::None` plans to `pure`
//!    (the Δ baseline), `BiasSpec::alibi` to `factored` (FlashBias);
//!    `dense` is the baseline the paper compares against. The
//!    predicted IO gap is the quantity Table 3 measures as Δ
//!    wall-clock.
//!
//!     cargo run --release --example serve_llm

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use flashbias::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RouteKey, Router,
    SessionApiError,
};
use flashbias::iomodel::Geometry;
use flashbias::plan::{
    BiasSpec, PjrtExecutor, PlanOptions, Planner, SessionError,
};
use flashbias::runtime::{HostValue, Runtime};
use flashbias::tensor::Tensor;
use flashbias::util::{human_secs, Xoshiro256};

const REQUESTS: usize = 48;

// ---------------------------------------------------------------------------
// act 1: streaming decode sessions on the host engine
// ---------------------------------------------------------------------------

/// Three sessions with ragged prefixes decoding in lockstep, plus a
/// one-shot request injected mid-stream — all through one coordinator.
fn streaming_sessions() -> anyhow::Result<()> {
    const C: usize = 64;
    const STEPS: usize = 24;
    let prefixes = [12usize, 40, 96];
    let geo = Geometry::square(256, C, 0, 100 * 1024 / 2);
    let planner = Planner::default();
    let opts = PlanOptions {
        causal: true,
        ..PlanOptions::default()
    };

    let mut coord = Coordinator::new(
        Arc::new(Runtime::empty()),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 6,
                max_wait: Duration::from_millis(1),
            },
            workers: 2,
            queue_depth: 64,
        },
    );
    coord.plan_and_register(
        "llm",
        &planner,
        &BiasSpec::alibi(256, 256, 0.25),
        &geo,
        &opts,
    )?;

    // the session API refuses bad requests with typed errors instead of
    // panicking a worker mid-stream
    match coord.open_session("no_such_plan") {
        Err(SessionApiError::UnknownPlan(name)) => {
            println!("  refused: open_session({name:?}) — unknown plan")
        }
        other => anyhow::bail!("expected UnknownPlan, got {other:?}"),
    }

    // open + prefill: one batched O(N·M) pass each fills the KV cache
    let mut rng = Xoshiro256::new(11);
    let mut sids = Vec::new();
    let mut prefill_ids = Vec::new();
    for &p in &prefixes {
        let sid = coord.open_session("llm")?;
        let q = Tensor::randn(&[p, C], 1.0, &mut rng);
        let k = Tensor::randn(&[p, C], 1.0, &mut rng);
        let v = Tensor::randn(&[p, C], 1.0, &mut rng);
        prefill_ids.push(coord.prefill(sid, q, k, v)?);
        sids.push(sid);
    }

    // decode: round-robin steps, so every flush interleaves sessions;
    // rid -> (session, step) recovers the stream each response feeds
    let t0 = Instant::now();
    let mut expect: HashMap<u64, (usize, usize)> = HashMap::new();
    let mut want = prefill_ids.len();
    for t in 0..STEPS {
        for (s, &sid) in sids.iter().enumerate() {
            let qr = rng.normal_vec(C, 1.0);
            let kr = rng.normal_vec(C, 1.0);
            let vr = rng.normal_vec(C, 1.0);
            expect.insert(coord.step(sid, &qr, &kr, &vr)?, (s, t));
            want += 1;
        }
        if t == STEPS / 2 {
            // one-shot traffic rides the same batcher: "prefill with
            // N > 1 and no session"
            let q = Tensor::randn(&[32, C], 1.0, &mut rng);
            let k = Tensor::randn(&[32, C], 1.0, &mut rng);
            let v = Tensor::randn(&[32, C], 1.0, &mut rng);
            let inputs = vec![
                HostValue::F32(q),
                HostValue::F32(k),
                HostValue::F32(v),
            ];
            coord
                .try_submit("llm", inputs)
                .map_err(|e| anyhow::anyhow!("one-shot refused: {e}"))?;
            want += 1;
        }
    }
    coord.flush_all()?;

    // drain; keep the last decoded "token" (output row) per session
    let mut last: Vec<Vec<f32>> = vec![Vec::new(); sids.len()];
    let mut got = 0usize;
    while got < want {
        let resp = coord
            .recv_timeout(Duration::from_secs(30))
            .ok_or_else(|| anyhow::anyhow!("decode stream stalled"))?;
        let outputs = resp
            .outputs
            .map_err(|e| anyhow::anyhow!("request {} failed: {e}", resp.id))?;
        if let Some(&(s, t)) = expect.get(&resp.id) {
            if t == STEPS - 1 {
                if let Some(tensor) = outputs[0].as_f32() {
                    last[s] = tensor.data().to_vec();
                }
            }
        }
        got += 1;
    }
    let wall = t0.elapsed().as_secs_f64();

    for (s, &sid) in sids.iter().enumerate() {
        let handle = coord
            .session(sid)
            .ok_or_else(|| anyhow::anyhow!("session {sid} vanished"))?;
        let st = handle.read();
        println!(
            "  session {s}: prefix {:3} + {STEPS} steps -> pos {:3}, \
             cache {:3} rows ({} B), carry l={:.3}, last out[..3] = \
             [{:+.3} {:+.3} {:+.3}]",
            prefixes[s],
            st.pos(),
            st.cache().len(),
            st.cache().resident_bytes(),
            st.carry().l,
            last[s][0],
            last[s][1],
            last[s][2],
        );
    }

    // a malformed step is a typed refusal — the cache is untouched
    let stub = vec![0.0f32; C];
    match coord.step(sids[0], &[1.0, 2.0, 3.0], &stub, &stub) {
        Err(SessionApiError::State(SessionError::ShapeMismatch {
            what,
            got,
            want,
        })) => println!(
            "  refused: step with a {got}-wide {what} (want {want}) — \
             session state untouched"
        ),
        other => anyhow::bail!("expected ShapeMismatch, got {other:?}"),
    }

    let m = coord.metrics();
    println!(
        "  {want} responses in {:.2}s | exec p50 {} | batches {} \
         (mean size {:.1}, mixed prefill+decode)",
        wall,
        human_secs(m.exec_stats().p50()),
        m.batches(),
        m.mean_batch_size(),
    );
    for sid in sids {
        coord.close_session(sid);
    }
    assert_eq!(coord.open_sessions(), 0);
    coord.shutdown();
    Ok(())
}

// ---------------------------------------------------------------------------
// act 2: one-shot variant serving over PJRT artifacts
// ---------------------------------------------------------------------------

fn serve_variant(rt: &Arc<Runtime>, variant: &str) -> anyhow::Result<()> {
    let router = Router::from_runtime(rt);
    let key = RouteKey::new("gpt2", variant);
    let max_n = router
        .max_bucket(&key)
        .ok_or_else(|| anyhow::anyhow!("no gpt2/{variant} artifacts"))?;

    let mut coord = Coordinator::new(
        rt.clone(),
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(2),
            },
            workers: 2,
            queue_depth: 64,
        },
    );

    // mixed-length stream: lengths uniform in [1, max_n], routed to the
    // smallest adequate bucket; token payloads drawn per request
    let mut rng = Xoshiro256::new(7);
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut exec_total = Duration::ZERO;
    for _ in 0..REQUESTS {
        let want_n = 1 + rng.next_below(max_n as u64) as usize;
        let (artifact, _bucket) =
            router.route(&key, want_n).ok_or_else(|| {
                anyhow::anyhow!(
                    "router has no gpt2/{variant} bucket for N={want_n} \
                     (max bucket {max_n})"
                )
            })?;
        let mut inputs = rt.example_inputs(artifact)?;
        // randomize the token input (the activation); weights reused
        let spec = rt.spec(artifact).ok_or_else(|| {
            anyhow::anyhow!("routed artifact {artifact} has no spec")
        })?;
        for &idx in &spec.activation_indices() {
            if let HostValue::I32(tokens, shape) = &inputs[idx] {
                let fresh: Vec<i32> = (0..tokens.len())
                    .map(|_| rng.next_below(512) as i32)
                    .collect();
                inputs[idx] = HostValue::I32(fresh, shape.clone());
            }
        }
        // bounded backpressure retry; responses drained while waiting
        // still count toward completion (and a non-retryable error —
        // unknown artifact, stopped pool — propagates instead of
        // spinning forever)
        flashbias::server::submit_with_retry(
            &mut coord,
            artifact,
            inputs,
            |resp| {
                match &resp.outputs {
                    Ok(_) => exec_total += resp.exec_time,
                    Err(_) => failed += 1,
                }
                completed += 1;
            },
        )?;
        submitted += 1;
    }
    coord.flush_all()?;
    while completed < submitted {
        match coord.recv_timeout(Duration::from_secs(120)) {
            Some(resp) => {
                // same accounting as the drain path above: record the
                // failure, keep draining, report after
                match &resp.outputs {
                    Ok(_) => exec_total += resp.exec_time,
                    Err(_) => failed += 1,
                }
                completed += 1;
            }
            None => anyhow::bail!("serve loop stalled"),
        }
    }
    if failed > 0 {
        anyhow::bail!("{failed} of {submitted} requests failed");
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "gpt2/{variant:9} {completed} reqs in {:.2}s = {:5.1} req/s | \
         exec p50 {} p99 {} | queue p50 {} | batches {} (mean size {:.1})",
        wall,
        completed as f64 / wall,
        human_secs(m.exec_stats().p50()),
        human_secs(m.exec_stats().p99()),
        human_secs(m.queue_stats().p50()),
        m.batches(),
        m.mean_batch_size(),
    );
    coord.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // --- what the planner says about the serving bias --------------------
    let planner = Planner::default();
    let geo = Geometry::square(256, 64, 0, 100 * 1024 / 2);
    let copts = PlanOptions {
        causal: true,
        ..PlanOptions::default()
    };
    let pure = planner.plan(&BiasSpec::None, &geo, &copts)?;
    let alibi =
        planner.plan(&BiasSpec::alibi(256, 256, 0.25), &geo, &copts)?;
    println!("serving plans (N=256 bucket):");
    println!("  no-bias: {}", pure.summary());
    println!("  alibi:   {}", alibi.summary());
    println!(
        "  predicted bias-processing IO: dense {:.3e} vs plan {:.3e} \
         ({:.1}x) — the Δ Table 3 measures\n",
        alibi.dense_io,
        alibi.predicted_io,
        alibi.io_saving()
    );

    println!(
        "streaming sessions (host engine): prefill once, then exact \
         1xM decode steps, continuously batched across sessions"
    );
    streaming_sessions()?;

    // variants come from the plans: pure (Δ baseline) and the planner's
    // pick for ALiBi; `dense` is the paper's comparison baseline
    let rt = match Runtime::open_default() {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!(
                "\none-shot PJRT serving skipped ({e}); run `make \
                 artifacts` for the full Table 3 stream"
            );
            return Ok(());
        }
    };
    println!(
        "\nserving GPT-2-shaped causal+ALiBi LM ({} requests/variant, \
         mixed lengths) through router -> batcher -> workers -> PJRT\n",
        REQUESTS
    );
    let variants = [
        PjrtExecutor::variant(&pure.mode),
        "dense",
        PjrtExecutor::variant(&alibi.mode),
    ];
    for variant in variants {
        serve_variant(&rt, variant)?;
    }
    println!(
        "\nTable 3 reading: Δ(dense − pure) vs Δ(factored − pure) is the \
         bias-processing overhead the paper reports; see \
         benches/table3_gpt2.rs for the per-iteration measurement."
    );
    Ok(())
}
