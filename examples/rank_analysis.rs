//! Spectral/energy analysis of learned relative-position biases —
//! regenerates the numbers behind Figures 6, 8 and 9 (SwinV2) and the
//! Pangu-Weather Appendix B setting, on the synthetic "trained" tables.
//!
//!     cargo run --release --example rank_analysis

use flashbias::bias::{pangu_relative_bias, swin_relative_bias};
use flashbias::linalg::{
    energy_spectrum, rank_for_energy, reconstruction_error, svd_factors,
};

fn main() {
    // --- Figure 6/8: SwinV2-like window bias, per-head rank@energy -------
    let window = (12, 12); // N = 144 (paper: 24² = 576, scaled)
    let heads = 8;
    println!("SwinV2-like window {window:?} (N = {}):",
             window.0 * window.1);
    println!("  head | rank@95% | rank@99% | rank@99.5% | err@R=16");
    let mut r99_all = Vec::new();
    for (h, bias) in swin_relative_bias(window, heads, 0, 6, 0.02)
        .iter()
        .enumerate()
    {
        let r95 = rank_for_energy(bias, 0.95);
        let r99 = rank_for_energy(bias, 0.99);
        let r995 = rank_for_energy(bias, 0.995);
        let (pq, pk) = svd_factors(bias, 16);
        let err = reconstruction_error(bias, &pq, &pk);
        println!("  {h:4} | {r95:8} | {r99:8} | {r995:10} | {err:.4}");
        r99_all.push(r99);
    }
    let mean_r99 =
        r99_all.iter().sum::<usize>() as f64 / r99_all.len() as f64;
    println!(
        "  mean rank@99% = {mean_r99:.1} of {} (paper Fig. 8: later-layer \
         heads well below full rank)",
        window.0 * window.1
    );

    // --- Figure 8's layer trend: noise level as a proxy for layer depth --
    println!("\nlayer-depth trend (noise ↓ ⇒ smoother ⇒ lower rank):");
    for (li, noise) in [0.08f32, 0.04, 0.02, 0.01].iter().enumerate() {
        let biases = swin_relative_bias(window, 4, li as u64, 6, *noise);
        let mean: f64 = biases
            .iter()
            .map(|b| rank_for_energy(b, 0.95) as f64)
            .sum::<f64>()
            / biases.len() as f64;
        println!("  layer~{li}: mean rank@95% = {mean:.1}");
    }

    // --- energy spectrum detail (Figure 6's 99.5% claim) -----------------
    let bias = &swin_relative_bias(window, 1, 42, 6, 0.02)[0];
    let cum = energy_spectrum(bias);
    println!("\nenergy spectrum (head 0): R=8 {:.4}, R=16 {:.4}, R=32 {:.4}",
             cum[7], cum[15], cum[31]);

    // --- Appendix B: Pangu 3-D window 2×6×12 = 144 -----------------------
    println!("\nPangu-Weather 3-D window (2, 6, 12) (N = 144):");
    for (h, bias) in pangu_relative_bias((2, 6, 12), 4, 0, 5, 0.02)
        .iter()
        .enumerate()
    {
        let r99 = rank_for_energy(bias, 0.99);
        let (pq, pk) = svd_factors(bias, 56); // paper: R = 56
        let err = reconstruction_error(bias, &pq, &pk);
        println!("  head {h}: rank@99% = {r99:3}, err@R=56 = {err:.5}");
    }
    println!("rank_analysis OK");
}
