//! Spectral/energy analysis of learned relative-position biases —
//! regenerates the numbers behind Figures 6, 8 and 9 (SwinV2) and the
//! Pangu-Weather Appendix B setting, on the synthetic "trained" tables —
//! and shows what the Table 1 planner decides for every head.
//!
//!     cargo run --release --example rank_analysis

use flashbias::bias::{pangu_relative_bias, swin_relative_bias};
use flashbias::iomodel::Geometry;
use flashbias::linalg::energy_spectrum;
use flashbias::plan::{BiasSpec, Decision, PlanOptions, Planner};

fn main() {
    let planner = Planner::default();
    let opts = PlanOptions::default();

    // --- Figure 6/8: SwinV2-like window bias, per-head plan --------------
    let window = (12, 12); // N = 144 (paper: 24² = 576, scaled)
    let n = window.0 * window.1;
    let heads = 8;
    let geo = Geometry::square(n, 32, 0, 100 * 1024 / 2);
    println!("SwinV2-like window {window:?} (N = {n}):");
    println!("  head | decision                  | rank | rel err | IO win");
    let mut r99_all = Vec::new();
    for (h, bias) in swin_relative_bias(window, heads, 0, 6, 0.02)
        .into_iter()
        .enumerate()
    {
        let plan = planner
            .plan(&BiasSpec::static_learned(bias), &geo, &opts)
            .expect("plan static table");
        let rank = plan.measured_rank();
        let (label, err) = match &plan.decision {
            Decision::Svd { rel_err, .. } => ("SVD", *rel_err),
            Decision::DenseFallback { .. } => ("dense-fallback", 0.0),
            other => panic!("unexpected decision {other:?}"),
        };
        println!(
            "  {h:4} | {label:25} | {rank:4} | {err:7.4} | {:5.1}x",
            plan.io_saving()
        );
        r99_all.push(rank);
    }
    let mean_r99 =
        r99_all.iter().sum::<usize>() as f64 / r99_all.len() as f64;
    println!(
        "  mean rank@99% = {mean_r99:.1} of {n} (paper Fig. 8: later-layer \
         heads well below full rank)"
    );

    // --- Figure 8's layer trend: noise level as a proxy for layer depth --
    println!("\nlayer-depth trend (noise ↓ ⇒ smoother ⇒ lower rank):");
    let mut per_layer_ranks = Vec::new();
    for (li, noise) in [0.08f32, 0.04, 0.02, 0.01].iter().enumerate() {
        let biases = swin_relative_bias(window, 4, li as u64, 6, *noise);
        let ranks: Vec<usize> = biases
            .into_iter()
            .map(|b| {
                planner
                    .plan(&BiasSpec::static_learned(b), &geo, &opts)
                    .expect("plan")
                    .measured_rank()
            })
            .collect();
        let mean: f64 = ranks.iter().sum::<usize>() as f64
            / ranks.len() as f64;
        println!("  layer~{li}: mean rank@99% = {mean:.1}");
        per_layer_ranks.push(*ranks.iter().max().unwrap());
    }
    let from = planner.factored_from(&per_layer_ranks, n);
    println!("  → §4.3 policy: factored from layer {from}");

    // --- energy spectrum detail (Figure 6's 99.5% claim) -----------------
    let bias = &swin_relative_bias(window, 1, 42, 6, 0.02)[0];
    let cum = energy_spectrum(bias);
    println!("\nenergy spectrum (head 0): R=8 {:.4}, R=16 {:.4}, R=32 {:.4}",
             cum[7], cum[15], cum[31]);

    // --- Appendix B: Pangu 3-D window 2×6×12 = 144 -----------------------
    println!("\nPangu-Weather 3-D window (2, 6, 12) (N = 144):");
    // the paper pins R = 56; an override bypasses the fraction test
    let pangu_opts = PlanOptions {
        rank_override: Some(56),
        ..PlanOptions::default()
    };
    for (h, bias) in pangu_relative_bias((2, 6, 12), 4, 0, 5, 0.02)
        .into_iter()
        .enumerate()
    {
        let measured = planner
            .plan(&BiasSpec::static_learned(bias.clone()), &geo, &opts)
            .expect("plan");
        let pinned = planner
            .plan(&BiasSpec::static_learned(bias), &geo, &pangu_opts)
            .expect("plan");
        let err56 = match &pinned.decision {
            Decision::Svd { rel_err, .. } => *rel_err,
            other => panic!("override must stay SVD, got {other:?}"),
        };
        println!(
            "  head {h}: planned rank = {:3}, err@R=56 = {err56:.5}",
            measured.rank()
        );
    }
    println!("rank_analysis OK");
}
