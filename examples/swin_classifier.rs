//! SwinV2-style window-attention classifier (§4.3 / Table 4): the SVD
//! deployment pipeline end-to-end — measure per-layer ranks, apply the
//! paper's "factored from layer L" policy via the strategy selector, and
//! check accuracy preservation on the PJRT artifacts.
//!
//!     make artifacts && cargo run --release --example swin_classifier

use flashbias::benchkit::{bench_artifact, time_once, Table};
use flashbias::bias::swin_relative_bias;
use flashbias::coordinator::{BiasClass, StrategySelector};
use flashbias::decompose::Strategy;
use flashbias::linalg::rank_for_energy;
use flashbias::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // --- 1. offline: measure per-layer ranks, pick the policy ------------
    let window = (12, 12);
    let n = window.0 * window.1;
    let layers = 4;
    let heads = 4;
    let selector = StrategySelector::default();
    let ranks: Vec<usize> = time_once("offline SVD rank scan", || {
        (0..layers)
            .map(|li| {
                swin_relative_bias(window, heads, li as u64, 6,
                                   0.08 / (li + 1) as f32)
                    .iter()
                    .map(|b| rank_for_energy(b, 0.99))
                    .max()
                    .unwrap()
            })
            .collect()
    });
    println!("per-layer max rank@99%: {ranks:?} (N = {n})");
    let from = selector.factored_from(&ranks, n);
    println!(
        "policy: FlashBias from layer {from} (paper §4.3: last-8-layers \
         rule on SwinV2-B)"
    );
    for (li, &r) in ranks.iter().enumerate() {
        let strat = selector.select(BiasClass::StaticLearned {
            rank_at_energy: r,
            full_rank: n,
        });
        let chosen = match strat {
            Strategy::Svd(_) => "SVD",
            Strategy::Dense => "dense",
            _ => "?",
        };
        println!("  layer {li}: rank@99%={r:3} -> {chosen}");
    }

    // --- 2. PJRT: accuracy + timing of the built artifacts ---------------
    let rt = Runtime::open_default()?;
    let dense =
        rt.load("swin_dense")?.run(&rt.example_inputs("swin_dense")?)?;
    let fact = rt
        .load("swin_factored")?
        .run(&rt.example_inputs("swin_factored")?)?;
    let (d, f) = (
        dense[0].as_f32().unwrap(),
        fact[0].as_f32().unwrap(),
    );
    let argmax = |t: &flashbias::tensor::Tensor| {
        t.data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    println!(
        "\nclass logits: rel err {:.4}, top-1 {} -> {} ({})",
        f.rel_err(d),
        argmax(d),
        argmax(f),
        if argmax(d) == argmax(f) {
            "preserved — Table 4's accuracy claim"
        } else {
            "CHANGED"
        }
    );
    assert_eq!(argmax(d), argmax(f));

    let mut table = Table::new("Swin window attention (N=144, 4 layers)");
    table.row(bench_artifact(&rt, "swin_dense", 2, 8));
    table.row(bench_artifact(&rt, "swin_factored", 2, 8));
    drop(table);
    println!("swin_classifier OK");
    Ok(())
}
