//! SwinV2-style window-attention classifier (§4.3 / Table 4): the SVD
//! deployment pipeline end-to-end through the unified plan API — declare
//! each layer's learned table as a `BiasSpec`, let the `Planner` run the
//! rank test and pick SVD-vs-dense per layer, execute through the host
//! backend, and (when artifacts are built) check accuracy preservation on
//! PJRT.
//!
//!     cargo run --release --example swin_classifier
//!     # optional PJRT section: make artifacts first

use flashbias::benchkit::{bench_artifact, time_once, Table};
use flashbias::bias::swin_relative_bias;
use flashbias::iomodel::Geometry;
use flashbias::plan::{self, BiasSpec, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn main() -> anyhow::Result<()> {
    // --- 1. offline: plan every layer, read the policy off the plans -----
    let window = (12, 12);
    let n = window.0 * window.1;
    let layers = 4;
    let heads = 4;
    let planner = Planner::default();
    let geo = Geometry::square(n, 32, 0, 100 * 1024 / 2);
    let opts = PlanOptions::default();
    // per-layer: plan each head's table; record the worst measured rank
    let plans: Vec<Vec<flashbias::plan::AttentionPlan>> =
        time_once("offline planning (rank scan + SVD)", || {
            (0..layers)
                .map(|li| {
                    swin_relative_bias(window, heads, li as u64, 6,
                                       0.08 / (li + 1) as f32)
                        .into_iter()
                        .map(|b| {
                            planner
                                .plan(&BiasSpec::static_learned(b), &geo,
                                      &opts)
                                .expect("planning a static table")
                        })
                        .collect()
                })
                .collect()
        });
    let ranks: Vec<usize> = plans
        .iter()
        .map(|layer| {
            layer.iter().map(|p| p.measured_rank()).max().unwrap()
        })
        .collect();
    println!("per-layer max rank@99%: {ranks:?} (N = {n})");
    let from = planner.factored_from(&ranks, n);
    println!(
        "policy: FlashBias from layer {from} (paper §4.3: last-8-layers \
         rule on SwinV2-B)"
    );
    for (li, layer) in plans.iter().enumerate() {
        let factored =
            layer.iter().filter(|p| p.rank() > 0).count();
        println!(
            "  layer {li}: {}/{} heads factored, modes: {:?}",
            factored,
            layer.len(),
            layer.iter().map(|p| p.mode_name()).collect::<Vec<_>>()
        );
    }

    // --- 2. execute one window through a factored plan -------------------
    let mut rng = Xoshiro256::new(7);
    let q = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let p0 = &plans[layers - 1][0]; // deepest layer: low-rank, factored
    let fact_out = plan::execute(p0, &q, &k, &v)?;
    let dense_out = flashbias::attention::attention(
        &q,
        &k,
        &v,
        Some(
            &swin_relative_bias(window, heads, (layers - 1) as u64, 6,
                                0.08 / layers as f32)[0],
        ),
        &flashbias::attention::AttnOpts::default(),
    );
    println!(
        "\nwindow attention through the plan: rel err vs dense bias \
         {:.4} (plan rel_err budget: SVD truncation)",
        fact_out.rel_err(&dense_out)
    );

    // --- 3. PJRT: accuracy + timing of the built artifacts (optional) ----
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\nPJRT section skipped ({e})");
            println!("swin_classifier OK");
            return Ok(());
        }
    };
    let dense =
        rt.load("swin_dense")?.run(&rt.example_inputs("swin_dense")?)?;
    let fact = rt
        .load("swin_factored")?
        .run(&rt.example_inputs("swin_factored")?)?;
    let (d, f) = (
        dense[0].as_f32().unwrap(),
        fact[0].as_f32().unwrap(),
    );
    let argmax = |t: &flashbias::tensor::Tensor| {
        t.data()
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    };
    println!(
        "\nclass logits: rel err {:.4}, top-1 {} -> {} ({})",
        f.rel_err(d),
        argmax(d),
        argmax(f),
        if argmax(d) == argmax(f) {
            "preserved — Table 4's accuracy claim"
        } else {
            "CHANGED"
        }
    );
    assert_eq!(argmax(d), argmax(f));

    let mut table = Table::new("Swin window attention (N=144, 4 layers)");
    table.row(bench_artifact(&rt, "swin_dense", 2, 8));
    table.row(bench_artifact(&rt, "swin_factored", 2, 8));
    drop(table);
    println!("swin_classifier OK");
    Ok(())
}
