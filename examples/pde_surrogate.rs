//! PDE-surrogate example (§4.4 / Example 3.5): spatial-distance-bias
//! attention over synthetic car-hull point clouds, through the unified
//! plan API — `BiasSpec::spatial → Planner (exact rank-9 factors) →
//! execute` — plus the Table 5 scaling story off the plan's cost model.
//!
//!     cargo run --release --example pde_surrogate
//!     # optional PJRT section: make artifacts first

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::{bench_artifact, Table};
use flashbias::bias::synthetic_car_cloud;
use flashbias::iomodel::{self, Geometry};
use flashbias::plan::{self, BiasSpec, ExecMode, PlanOptions, Planner};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::{human_bytes, Xoshiro256};

fn main() -> anyhow::Result<()> {
    // --- 1. plan the exact factorization on a real cloud -----------------
    let n = 2048;
    let cloud = synthetic_car_cloud(n, 0);
    let mut rng = Xoshiro256::new(1);
    let alpha: Vec<f32> =
        (0..n).map(|_| rng.uniform(0.5, 2.0) as f32).collect();
    let spec =
        BiasSpec::spatial(cloud.clone(), cloud.clone(), Some(alpha));
    let geo = Geometry::square(n, 32, 0, 100 * 1024 / 2);
    let planner = Planner::default();
    // verify_exact: double-check the closed form against the dense matrix
    let planop = PlanOptions {
        verify_exact: true,
        ..PlanOptions::default()
    };
    let plan = planner.plan(&spec, &geo, &planop)?;
    println!(
        "Example 3.5 on a {n}-point car hull: {}",
        plan.summary()
    );
    let rel_err = match &plan.mode {
        ExecMode::Factored { factors } => factors.rel_err,
        _ => panic!("spatial bias must plan as exact factors"),
    };
    println!("exact factorization rel err: {rel_err:.2e}");
    println!(
        "bias storage: dense {} -> factored {}",
        human_bytes((n * n * 4) as u64),
        human_bytes(plan.bias_storage_bytes as u64)
    );

    // --- 2. executed cross-attention equals the dense-bias reference -----
    let q = Tensor::randn(&[64, 32], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 32], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 32], 1.0, &mut rng);
    // cross-attention: 64 query points against the full hull — re-plan at
    // the rectangular geometry with the matching spec rows
    let alpha64: Vec<f32> = (0..64)
        .map(|i| {
            match &spec {
                BiasSpec::Spatial(s) => {
                    s.alpha.as_ref().map(|a| a[i]).unwrap_or(1.0)
                }
                _ => 1.0,
            }
        })
        .collect();
    let xq64 = cloud.slice_rows(0, 64);
    let cross_spec =
        BiasSpec::spatial(xq64, cloud.clone(), Some(alpha64));
    let cross_geo = Geometry {
        n: 64,
        m: n,
        c: 32,
        r: 0,
        sram: geo.sram,
    };
    let cross_plan =
        planner.plan(&cross_spec, &cross_geo, &PlanOptions::default())?;
    let o_fact = plan::execute(&cross_plan, &q, &k, &v)?;
    let bias_rows = cross_spec.materialize().unwrap();
    let o_dense = attention::attention(&q, &k, &v, Some(&bias_rows),
                                       &AttnOpts::default());
    println!(
        "cross-attention plan↔dense rel err: {:.2e}",
        o_fact.rel_err(&o_dense)
    );
    assert!(o_fact.rel_err(&o_dense) < 1e-3);

    // --- 3. the Table 5 scaling story via the plan's cost model ----------
    println!("\nTable 5 scaling (plan-predicted, training step, per head):");
    for &nn in &[8192usize, 16384, 32186] {
        let cl = synthetic_car_cloud(nn, 2);
        let s = BiasSpec::spatial(cl.clone(), cl, None);
        let g = Geometry::square(nn, 128, 0, 100 * 1024 / 2);
        let p = planner.plan(&s, &g, &PlanOptions::default())?;
        let dense_mem =
            iomodel::training_memory_elems(&p.geometry, true) * 4;
        let fact_mem =
            iomodel::training_memory_elems(&p.geometry, false) * 4;
        println!(
            "  N={nn:6}: rank {} plan, {:.1}x IO saving, memory dense {} \
             vs FlashBias {} ({}x)",
            p.rank(),
            p.io_saving(),
            human_bytes(dense_mem as u64),
            human_bytes(fact_mem as u64),
            dense_mem / fact_mem
        );
    }

    // --- 4. PJRT: the full 2-layer solver (optional) ----------------------
    match Runtime::open_default() {
        Ok(rt) => {
            let mut table = Table::new(
                "PDE solver fwd (N=512, H=8, 2 layers) — Table 5 shape",
            );
            for name in
                ["pde_nobias_n512", "pde_dense_n512", "pde_factored_n512"]
            {
                table.row(bench_artifact(&rt, name, 2, 8));
            }
            for name in
                ["pde_train_dense_n512", "pde_train_factored_n512"]
            {
                let mut row = bench_artifact(&rt, name, 1, 4);
                row.note =
                    "train step (α gradients flow through the bias)"
                        .into();
                table.row(row);
            }
            drop(table);
        }
        Err(e) => println!("\nPJRT section skipped ({e})"),
    }
    println!("pde_surrogate OK");
    Ok(())
}
