//! PDE-surrogate example (§4.4 / Example 3.5): spatial-distance-bias
//! attention over synthetic car-hull point clouds — dense vs exact rank-9
//! factorization, host-side decomposition + PJRT execution.
//!
//!     make artifacts && cargo run --release --example pde_surrogate

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::{bench_artifact, Table};
use flashbias::bias::{synthetic_car_cloud, ExactBias, SpatialDistance};
use flashbias::iomodel::{self, Geometry};
use flashbias::runtime::Runtime;
use flashbias::util::{human_bytes, Xoshiro256};

fn main() -> anyhow::Result<()> {
    // --- 1. host-side: the exact factorization on a real cloud ----------
    let n = 2048;
    let cloud = synthetic_car_cloud(n, 0);
    let mut rng = Xoshiro256::new(1);
    let alpha: Vec<f32> =
        (0..n).map(|_| rng.uniform(0.5, 2.0) as f32).collect();
    let bias = SpatialDistance::new(cloud.clone(), cloud.clone(),
                                    Some(alpha));
    let (pq, pk) = bias.factors();
    let dense = bias.dense();
    let err = pq.matmul_t(&pk).rel_err(&dense);
    println!(
        "Example 3.5 on a {n}-point car hull: rank {} exact factorization, \
         rel err {err:.2e}",
        bias.rank()
    );
    println!(
        "bias storage: dense {} -> factored {}",
        human_bytes(dense.size_bytes() as u64),
        human_bytes((pq.size_bytes() + pk.size_bytes()) as u64)
    );

    // attention through the factors equals dense-bias attention
    let q = flashbias::tensor::Tensor::randn(&[64, 32], 1.0, &mut rng);
    let k = flashbias::tensor::Tensor::randn(&[n, 32], 1.0, &mut rng);
    let v = flashbias::tensor::Tensor::randn(&[n, 32], 1.0, &mut rng);
    let bias_rows = dense.slice_rows(0, 64);
    let pq_rows = pq.slice_rows(0, 64);
    let o_dense = attention::attention(&q, &k, &v, Some(&bias_rows),
                                       &AttnOpts::default());
    let o_fact = attention::attention_factored(&q, &k, &v, &pq_rows, &pk,
                                               &AttnOpts::default());
    println!("cross-attention dense↔factored rel err: {:.2e}",
             o_fact.rel_err(&o_dense));
    assert!(o_fact.rel_err(&o_dense) < 1e-3);

    // --- 2. PJRT: the full 2-layer solver, three variants ----------------
    let rt = Runtime::open_default()?;
    let mut table = Table::new(
        "PDE solver fwd (N=512, H=8, 2 layers) — Table 5 shape",
    );
    for name in ["pde_nobias_n512", "pde_dense_n512", "pde_factored_n512"] {
        table.row(bench_artifact(&rt, name, 2, 8));
    }
    for name in ["pde_train_dense_n512", "pde_train_factored_n512"] {
        let mut row = bench_artifact(&rt, name, 1, 4);
        row.note = "train step (α gradients flow through the bias)".into();
        table.row(row);
    }
    drop(table);

    // --- 3. the Table 5 scaling story via the IO model --------------------
    println!("\nTable 5 scaling (model, training step, per head):");
    for &nn in &[8192usize, 16384, 32186] {
        let g = Geometry::square(nn, 128, 9, 100 * 1024 / 2);
        let dense_mem = iomodel::training_memory_elems(&g, true) * 4;
        let fact_mem = iomodel::training_memory_elems(&g, false) * 4;
        println!(
            "  N={nn:6}: dense {} vs FlashBias {}  ({}x)",
            human_bytes(dense_mem as u64),
            human_bytes(fact_mem as u64),
            dense_mem / fact_mem
        );
    }
    println!("pde_surrogate OK");
    Ok(())
}
