//! AlphaFold-3-style Pairformer example (§4.4, Tables 6/9): triangle
//! attention whose bias is projected from the pair representation —
//! the *dynamic* bias case that only neural decomposition handles.
//!
//! The neural φ̂ nets were trained offline at AOT time (Eq. 5) and baked
//! into the `pairformer_neural` artifact; here we run both variants,
//! compare outputs (Table 6's "no loss of accuracy"), and demonstrate the
//! rust-side neural decomposition on a fresh dynamic bias.
//!
//!     make artifacts && cargo run --release --example fold_pairformer

use flashbias::benchkit::{bench_artifact, Table};
use flashbias::decompose::{NeuralConfig, NeuralDecomposition};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;

    // --- 1. dense vs neural through PJRT ---------------------------------
    let run = |name: &str| -> anyhow::Result<Tensor> {
        let out = rt.load(name)?.run(&rt.example_inputs(name)?)?;
        Ok(out[0].as_f32().unwrap().clone())
    };
    let dense = run("pairformer_dense")?;
    let neural = run("pairformer_neural")?;
    let rel = neural.rel_err(&dense);
    println!(
        "Pairformer single-rep output: neural-decomposed vs dense bias \
         rel err = {rel:.3} (Table 6: metric fluctuation within noise)"
    );
    assert!(rel < 0.35, "neural decomposition diverged: {rel}");

    let mut table = Table::new("Pairformer block (N=128, H=4, 2 layers)");
    table.row(bench_artifact(&rt, "pairformer_dense", 2, 8));
    table.row(bench_artifact(&rt, "pairformer_neural", 2, 8));
    drop(table);

    // --- 2. rust-side neural decomposition of a fresh dynamic bias -------
    // (what the coordinator would do for a new layer at deployment time)
    let n = 64;
    let mut rng = Xoshiro256::new(3);
    // synthetic pair-rep-like sources: smooth low-dim token features
    let x = Tensor::from_fn(&[n, 4], |ix| {
        let t = ix[0] as f32 / n as f32;
        match ix[1] {
            0 => (6.28 * t).sin(),
            1 => (6.28 * t).cos(),
            2 => t,
            _ => 1.0,
        }
    });
    // dynamic target: a data-dependent kernel of the sources
    let w = Tensor::randn(&[4, 4], 0.8, &mut rng);
    let proj = x.matmul(&w);
    let target = proj.matmul_t(&proj).map(|v| (0.5 * v).tanh());
    let cfg = NeuralConfig {
        rank: 12,
        hidden: 48,
        steps: 1200,
        lr: 5e-3,
        ..NeuralConfig::default()
    };
    let t0 = std::time::Instant::now();
    let nd = NeuralDecomposition::fit(&x, &x, &target, &cfg, &mut rng);
    let approx = nd.phi_q(&x).matmul_t(&nd.phi_k(&x));
    println!(
        "\nfresh dynamic bias (N={n}): neural decomposition R={} fitted in \
         {:.1}s, rel err {:.3} (loss {:.4} -> {:.4})",
        cfg.rank,
        t0.elapsed().as_secs_f64(),
        approx.rel_err(&target),
        nd.loss_history.first().unwrap(),
        nd.loss_history.last().unwrap(),
    );
    assert!(approx.rel_err(&target) < 0.3);
    println!("fold_pairformer OK");
    Ok(())
}
