//! AlphaFold-3-style Pairformer example (§4.4, Tables 6/9): triangle
//! attention whose bias is projected from the pair representation —
//! the *dynamic* bias case. Through the plan API this is just another
//! `BiasSpec`: declare the token sources and the sample's dense bias,
//! and the `Planner` routes it to the neural decomposition (Eq. 5) and
//! emits a factored plan.
//!
//!     cargo run --release --example fold_pairformer
//!     # optional PJRT section: make artifacts first

use flashbias::attention::{self, AttnOpts};
use flashbias::benchkit::{bench_artifact, Table};
use flashbias::decompose::NeuralConfig;
use flashbias::iomodel::Geometry;
use flashbias::plan::{
    self, BiasSpec, Decision, PlanOptions, Planner, SelectorConfig,
};
use flashbias::runtime::Runtime;
use flashbias::tensor::Tensor;
use flashbias::util::Xoshiro256;

fn main() -> anyhow::Result<()> {
    // --- 1. plan a fresh dynamic bias ------------------------------------
    // (what the coordinator does for a new layer at deployment time)
    let n = 64;
    let mut rng = Xoshiro256::new(3);
    // synthetic pair-rep-like sources: smooth low-dim token features
    let x = Tensor::from_fn(&[n, 4], |ix| {
        let t = ix[0] as f32 / n as f32;
        match ix[1] {
            0 => (6.28 * t).sin(),
            1 => (6.28 * t).cos(),
            2 => t,
            _ => 1.0,
        }
    });
    // dynamic target: a data-dependent kernel of the sources
    let w = Tensor::randn(&[4, 4], 0.8, &mut rng);
    let proj = x.matmul(&w);
    let target = proj.matmul_t(&proj).map(|v| (0.5 * v).tanh());

    let planner = Planner::new(SelectorConfig {
        neural: NeuralConfig {
            rank: 12,
            hidden: 48,
            steps: 1200,
            lr: 5e-3,
            ..NeuralConfig::default()
        },
        ..SelectorConfig::default()
    });
    let spec = BiasSpec::dynamic(x.clone(), x.clone(), target.clone());
    let geo = Geometry::square(n, 16, 0, 100 * 1024 / 2);
    let t0 = std::time::Instant::now();
    let dplan = planner.plan(&spec, &geo, &PlanOptions::default())?;
    let (rank, rel_err) = match &dplan.decision {
        Decision::Neural { rank, rel_err } => (*rank, *rel_err),
        other => panic!("dynamic bias must plan neural, got {other:?}"),
    };
    println!(
        "fresh dynamic bias (N={n}): planned {} with R={rank} in {:.1}s, \
         rel err {rel_err:.3}",
        dplan.mode_name(),
        t0.elapsed().as_secs_f64(),
    );
    assert!(rel_err < 0.3, "neural decomposition diverged: {rel_err}");

    // --- 2. the factored plan executes close to the dense reference ------
    let q = Tensor::randn(&[n, 16], 1.0, &mut rng);
    let k = Tensor::randn(&[n, 16], 1.0, &mut rng);
    let v = Tensor::randn(&[n, 16], 1.0, &mut rng);
    let approx = plan::execute(&dplan, &q, &k, &v)?;
    let exact = attention::attention(&q, &k, &v, Some(&target),
                                     &AttnOpts::default());
    println!(
        "attention through the neural plan: rel err vs dense bias {:.3}",
        approx.rel_err(&exact)
    );
    assert!(approx.rel_err(&exact) < 0.35);

    // --- 3. dense vs neural through PJRT (optional) ----------------------
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            println!("\nPJRT section skipped ({e})");
            println!("fold_pairformer OK");
            return Ok(());
        }
    };
    let run = |name: &str| -> anyhow::Result<Tensor> {
        let out = rt.load(name)?.run(&rt.example_inputs(name)?)?;
        Ok(out[0].as_f32().unwrap().clone())
    };
    let dense = run("pairformer_dense")?;
    let neural = run("pairformer_neural")?;
    let rel = neural.rel_err(&dense);
    println!(
        "Pairformer single-rep output: neural-decomposed vs dense bias \
         rel err = {rel:.3} (Table 6: metric fluctuation within noise)"
    );
    assert!(rel < 0.35, "neural decomposition diverged: {rel}");

    let mut table = Table::new("Pairformer block (N=128, H=4, 2 layers)");
    table.row(bench_artifact(&rt, "pairformer_dense", 2, 8));
    table.row(bench_artifact(&rt, "pairformer_neural", 2, 8));
    drop(table);
    println!("fold_pairformer OK");
    Ok(())
}
